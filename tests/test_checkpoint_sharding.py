
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.int32)}}
    save_checkpoint(str(tmp_path), 7, tree, {"note": "x"})
    restored, meta = load_checkpoint(str(tmp_path), tree)
    assert meta["step"] == 7 and meta["note"] == "x"
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path):
    tree = {"a": jnp.zeros(2)}
    assert latest_step(str(tmp_path)) is None
    save_checkpoint(str(tmp_path), 1, tree)
    save_checkpoint(str(tmp_path), 12, tree)
    assert latest_step(str(tmp_path)) == 12


# ------------------------------------------------------------ sharding -----
def test_param_specs_respect_divisibility():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import param_spec

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)

    mesh = FakeMesh()
    # vocab 256000 div 16 → model on dim0; d_model 3072 div 16 → data on dim1
    assert param_spec(["embed", "tok"], (256000, 3072), mesh) == P("model", "data")
    # stacked block param: leading L dim never sharded
    spec = param_spec(["blocks", "ffn", "w_up"], (28, 3072, 24576), mesh)
    assert spec[0] is None and "model" in spec
    # indivisible dims → replicated
    assert param_spec(["x"], (7, 13), mesh) == P(None, None)
    # bias vector
    assert param_spec(["attn", "b_q"], (4096,), mesh) == P("model")


def test_cache_specs():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import cache_spec

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)

    mesh = FakeMesh()
    # (L, B, S, hkv, dh): batch over data, dh over model, S never sharded
    spec = cache_spec(["blocks", "k"], (126, 128, 32768, 8, 128), mesh)
    assert spec[1] == "data"
    assert spec[2] is None
    assert spec[4] == "model"


def test_batch_specs():
    from jax.sharding import PartitionSpec as P
    from repro.launch.sharding import batch_spec

    class FakeMesh:
        axis_names = ("data", "model")
        class devices:
            shape = (16, 16)

    m = FakeMesh()
    assert batch_spec((256, 4096), m) == P("data", None)
    assert batch_spec((1, 524288), m) == P(None, None)
