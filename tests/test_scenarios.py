"""Scenario registry + iterative-engine session coverage.

* registry round-trips: get/replace/hash, duplicate rejection, tag queries,
  smoke shrinking;
* every registered scenario builds and completes a one-shot session (tiny
  budgets, smoke sizes) with the paper's 3 comm times;
* the iterative baselines' ledgers count exactly one up + one down transfer
  per round in BOTH engine execution modes, with byte-identical totals;
* the engine's iterative session cache re-serves compiled programs across
  calls (the no-recompile contract of DESIGN.md §8).
"""
import dataclasses

import jax
import pytest

from repro import scenarios
from repro.core import (IterativeConfig, ProtocolConfig, run_one_shot,
                        run_vanilla)
from repro.engine import iterative


def test_registry_roundtrip():
    names = scenarios.names()
    assert len(names) >= 12
    spec = scenarios.get("hard/overlap-32")
    assert spec.name == "hard/overlap-32"
    clone = dataclasses.replace(spec)
    assert clone == spec and hash(clone) == hash(spec)
    assert spec.budget("client_epochs", 1) == 80
    assert spec.budget("not-a-budget", 7) == 7
    with pytest.raises(KeyError):
        scenarios.get("no/such-scenario")
    with pytest.raises(ValueError):
        scenarios.register(spec)            # duplicate name rejected


def test_catalog_covers_the_papers_axes():
    assert {f"credit/overlap-{n}" for n in (32, 2048)} <= set(scenarios.names())
    assert any(s.num_parties == 8 for s in scenarios.by_tag("parties"))
    assert any(s.image_grid for s in scenarios.by_tag("image"))
    assert len(scenarios.by_tag("smoke")) >= 2
    skew = scenarios.get("credit/feature-skew")
    assert skew.feature_sizes[0] > 3 * skew.feature_sizes[1]


def test_smoke_variant_shrinks_but_preserves_condition():
    spec = scenarios.get("credit/overlap-2048")
    small = spec.smoke()
    assert small.overlap <= spec.smoke_overlap
    assert small.num_samples <= spec.smoke_samples
    assert small.name == spec.name
    assert small.gen_params == spec.gen_params


@pytest.mark.parametrize("name", scenarios.names())
def test_every_scenario_builds_and_runs_one_shot(name):
    bundle = scenarios.build(name, seed=0, smoke=True)
    spec = bundle.spec
    assert len(bundle.split.aligned) == spec.num_parties
    assert len(bundle.extractors) == spec.num_parties
    if spec.overlap_capacity is None:
        assert bundle.split.labels.shape[0] == spec.overlap
        assert bundle.split.aligned_mask is None
    else:
        # equal-shape family (DESIGN.md §14): the aligned block is padded
        # to the fixed capacity; the mask marks the N_o real rows
        assert bundle.split.labels.shape[0] == spec.overlap_capacity
        assert int(bundle.split.aligned_mask.sum()) == spec.overlap
    res = run_one_shot(jax.random.PRNGKey(0), bundle.split, bundle.extractors,
                       bundle.ssl_cfgs,
                       ProtocolConfig(client_epochs=1, server_epochs=1))
    assert res.ledger.comm_times() == 3         # THE paper invariant
    assert 0.0 <= res.metric <= 1.0


@pytest.mark.parametrize("mode", ["scan", "python"])
def test_iterative_ledger_counts_one_up_one_down_per_round(mode):
    bundle = scenarios.build("credit/overlap-64", seed=0, smoke=True)
    res = run_vanilla(jax.random.PRNGKey(1), bundle.split, bundle.extractors,
                      bundle.ssl_cfgs,
                      IterativeConfig(iterations=25, engine_mode=mode))
    # 2 rounds (reps up, grads down) per iteration per client
    assert res.ledger.comm_times() == 2 * 25
    ups = [e for e in res.ledger.events if e.direction == "up"]
    downs = [e for e in res.ledger.events if e.direction == "down"]
    assert len(ups) == len(downs) == 25 * 2      # per client per iteration
    bs, rep = 32, bundle.spec.rep_dim
    assert res.ledger.total_bytes() == 25 * 2 * 2 * bs * rep * 4
    assert res.diagnostics["engine_path"] == mode


def test_iterative_engine_modes_agree():
    bundle = scenarios.build("credit/overlap-64", seed=0, smoke=True)
    runs = {}
    for mode in ("scan", "python"):
        res = run_vanilla(jax.random.PRNGKey(2), bundle.split,
                          bundle.extractors, bundle.ssl_cfgs,
                          IterativeConfig(iterations=30, engine_mode=mode))
        runs[mode] = res
    assert abs(runs["scan"].metric - runs["python"].metric) < 1e-4
    assert (runs["scan"].ledger.total_bytes()
            == runs["python"].ledger.total_bytes())


def test_iterative_session_cache_reuses_compiled_program():
    iterative.clear_session_cache()
    bundle = scenarios.build("hard/overlap-32", seed=0, smoke=True)
    cfg = IterativeConfig(iterations=10, engine_mode="scan")
    for seed in (0, 1):
        run_vanilla(jax.random.PRNGKey(seed), bundle.split, bundle.extractors,
                    bundle.ssl_cfgs, cfg)
    stats = iterative.session_cache_stats()
    assert stats["misses"] == 1                  # compiled exactly once
    assert stats["hits"] == 1                    # second session re-served
    # fresh-but-equivalent extractors (same factory arguments) also hit
    b2 = scenarios.build("hard/overlap-32", seed=2, smoke=True)
    run_vanilla(jax.random.PRNGKey(3), b2.split, b2.extractors, b2.ssl_cfgs,
                cfg)
    assert iterative.session_cache_stats()["hits"] == 2
