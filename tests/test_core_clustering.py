import jax
import jax.numpy as jnp
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.clustering import (align_pseudo_to_true, cluster_purity,
                                   gradient_pseudo_labels, kmeans)


def _separable_gradients(key, n, c, d, noise=0.05):
    """Synthetic partial gradients: per-class direction + noise — the
    structure the paper's step ③ relies on."""
    k1, k2, k3 = jax.random.split(key, 3)
    dirs = jax.random.normal(k1, (c, d))
    dirs = dirs / jnp.linalg.norm(dirs, axis=1, keepdims=True)
    labels = jax.random.randint(k2, (n,), 0, c)
    g = dirs[labels] + noise * jax.random.normal(k3, (n, d))
    return g, labels


def test_kmeans_recovers_separable_classes():
    g, labels = _separable_gradients(jax.random.PRNGKey(0), 400, 10, 64)
    pseudo = gradient_pseudo_labels(jax.random.PRNGKey(1), g, 10)
    assert cluster_purity(pseudo, labels, 10) > 0.95


def test_kmeans_pallas_path_matches_jnp():
    g, _ = _separable_gradients(jax.random.PRNGKey(2), 200, 5, 32)
    a1, _ = kmeans(jax.random.PRNGKey(3), g, 5, use_kernel=False)
    a2, _ = kmeans(jax.random.PRNGKey(3), g, 5, use_kernel=True)
    assert jnp.array_equal(a1, a2)


def test_purity_bounds():
    pseudo = jnp.array([0, 0, 1, 1])
    true = jnp.array([1, 1, 0, 0])
    assert cluster_purity(pseudo, true, 2) == 1.0   # permutation-invariant
    true2 = jnp.array([0, 1, 0, 1])
    assert cluster_purity(pseudo, true2, 2) == 0.5


def test_align_pseudo_to_true():
    pseudo = jnp.array([0, 0, 1, 1, 2, 2])
    true = jnp.array([2, 2, 0, 0, 1, 1])
    aligned = align_pseudo_to_true(pseudo, true, 3)
    assert jnp.array_equal(aligned, true)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 100), c=st.integers(2, 6))
def test_property_kmeans_labels_in_range(seed, c):
    g, _ = _separable_gradients(jax.random.PRNGKey(seed), 64, c, 16)
    pseudo = gradient_pseudo_labels(jax.random.PRNGKey(seed + 1), g, c,
                                    num_iters=5)
    assert int(pseudo.min()) >= 0
    assert int(pseudo.max()) < c


@settings(max_examples=5, deadline=None)
@given(scale=st.floats(0.5, 20.0))
def test_property_kmeans_scale_invariant(scale):
    """Gradient magnitude encodes confidence, not class — clustering must be
    invariant to global rescaling (cosine k-means)."""
    g, _ = _separable_gradients(jax.random.PRNGKey(7), 128, 4, 16)
    a1, _ = kmeans(jax.random.PRNGKey(8), g, 4)
    a2, _ = kmeans(jax.random.PRNGKey(8), g * scale, 4)
    assert jnp.array_equal(a1, a2)
