import jax
import jax.numpy as jnp
import pytest

from repro import optim


def _quadratic_min(tx, steps=200):
    params = {"w": jnp.array([3.0, -2.0]), "b": jnp.array(1.5)}
    state = tx.init(params)

    def loss_fn(p):
        return jnp.sum(p["w"] ** 2) + p["b"] ** 2

    for _ in range(steps):
        grads = jax.grad(loss_fn)(params)
        updates, state = tx.update(grads, state, params)
        params = optim.apply_updates(params, updates)
    return loss_fn(params)


def test_sgd_converges_quadratic():
    assert _quadratic_min(optim.sgd(0.1)) < 1e-6


def test_sgd_momentum_converges():
    assert _quadratic_min(optim.sgd(0.05, momentum=0.9)) < 1e-6


def test_adam_converges():
    assert _quadratic_min(optim.adam(0.1)) < 1e-4


def test_adamw_decays_weights():
    tx = optim.adamw(0.01, weight_decay=0.5)
    params = {"w": jnp.ones(3)}
    state = tx.init(params)
    zero_grads = {"w": jnp.zeros(3)}
    updates, _ = tx.update(zero_grads, state, params)
    assert float(updates["w"][0]) < 0.0  # decay pulls toward zero


def test_clip_by_global_norm():
    tx = optim.clip_by_global_norm(1.0)
    g = {"a": jnp.full((4,), 10.0)}
    clipped, _ = tx.update(g, tx.init(g), None)
    norm = jnp.sqrt(jnp.sum(clipped["a"] ** 2))
    assert float(norm) == pytest.approx(1.0, rel=1e-5)


def test_chain_order():
    tx = optim.chain(optim.clip_by_global_norm(1.0), optim.scale(-0.5))
    g = {"a": jnp.array([3.0, 4.0])}  # norm 5 → clip to 1 → scale -0.5
    out, _ = tx.update(g, tx.init(g), None)
    assert jnp.allclose(out["a"], jnp.array([-0.3, -0.4]), atol=1e-6)


def test_schedules():
    from repro.optim import cosine_decay, linear_warmup_cosine

    s = cosine_decay(1.0, 100)
    assert float(s(jnp.array(0))) == pytest.approx(1.0)
    assert float(s(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)
    w = linear_warmup_cosine(1.0, 10, 100)
    assert float(w(jnp.array(5))) == pytest.approx(0.5, rel=1e-5)
    assert float(w(jnp.array(100))) == pytest.approx(0.0, abs=1e-6)


def test_jittable_step():
    tx = optim.chain(optim.clip_by_global_norm(1.0), optim.adam(1e-2))
    params = {"w": jnp.ones((4, 4))}
    state = tx.init(params)

    @jax.jit
    def step(p, s):
        g = jax.grad(lambda pp: jnp.sum(pp["w"] ** 2))(p)
        u, s = tx.update(g, s, p)
        return optim.apply_updates(p, u), s

    p2, s2 = step(params, state)
    assert p2["w"].shape == (4, 4)
    assert float(jnp.sum(p2["w"])) < 16.0
