import os
import sys

# tests run on the single host device; the dry-run (and only the dry-run)
# forces 512 placeholder devices in its own process
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_platform_name", "cpu")
