"""Fault-injected VFL (DESIGN.md §16): declarative ``FaultSpec`` party
faults — dropout at a named protocol stage, stragglers, DP-noised
uploads, representation-only parties — threaded through the one-shot /
few-shot protocol and the iterative baselines, and the frontier gate's
graceful-degradation floors:

* ``FaultSpec`` construction/validation and its pure predicate surface
  (``drops`` / ``skips_ssl`` / ``parties_survived`` /
  ``iterative_active_steps``);
* fold parity: a faulted C×S grid through ``run_scenarios_seeds`` ==
  the per-scenario ``run_seeds`` loop at 1e-5 with byte-identical
  per-entry ledgers (one-shot, few-shot, AND the iterative scan fold
  with its retry-inflated dropout ledgers), and the faulted seed fold ==
  the unfolded single-``run_one_shot`` calls;
* faults are data, not structure: changing the fault assignment on a
  warm fold adds ZERO fresh session-cache misses (the masks/keys ride
  the stacked programs as arguments, never as cache-key shape);
* an all-``None`` fault grid is byte- and metric-identical to the
  fault-free call — the healthy path must not feel the plumbing;
* the iterative dropout model: ledger-visible ``retry_reps`` /
  ``retry_timeout`` rounds, ``fault_modeled`` honesty on unmodeled
  kinds, and the few-shot+finetune refusal;
* ``check_gate``'s fault floors on hand-built row blobs (missing family
  members, wrong survivor counts, missing retry cost, broken
  degradation, the zero-fault-rows full-sweep rule).
"""
import json
import os
import sys

import jax
import jax.numpy as jnp
import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import frontier
from repro import engine
from repro.core import (IterativeConfig, ProtocolConfig, SSLConfig,
                        run_few_shot, run_one_shot, run_vanilla)
from repro.core.protocol import (_few_shot_finetune_seeds,
                                 run_scenarios_seeds, run_seeds)
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor
from repro.scenarios.faults import (ITERATIVE_DROP_FRACTION, POINT_EVAL,
                                    POINT_ROUND2, POINT_SSL, POINT_UPLOAD1,
                                    FaultSpec)

_FAST = ProtocolConfig(client_epochs=2, server_epochs=3)
SEEDS = (0, 1)
_SSL = [SSLConfig(modality="tabular")] * 2

FA_DROP = FaultSpec("dropout", party=1, stage="pre_ssl")
FA_STRAG = FaultSpec("straggler", party=0, epoch_fraction=0.5)
FA_DP = FaultSpec("dp_upload", party=1, dp_sigma=0.5)
FA_REP = FaultSpec("representation_only", party=1)

#: the C=2 × S=2 mixed grid every fold-parity test sweeps: a dropped
#: party next to a HEALTHY entry in the same fold (the healthy twin must
#: not feel its neighbors), a straggler next to a frozen party
_FAULTS = [[FA_DROP, None], [FA_STRAG, FA_REP]]


def _ext():
    return [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]


def _scenario_splits(c, overlap=64):
    out = []
    for s in SEEDS:
        x, y = make_tabular_credit(jax.random.PRNGKey(7000 + 97 * c + s), 700)
        out.append(make_vfl_partition(x[:, :22], y, overlap_size=overlap,
                                      feature_sizes=[11, 11], seed=s))
    return out


@pytest.fixture(scope="module")
def grid_splits():
    return [_scenario_splits(0), _scenario_splits(1)]


def _run_grid(runner, grid_splits, cfg=_FAST, faults=None):
    num_scenarios = len(grid_splits)
    kw = {} if faults is None else {"faults": faults}
    return run_scenarios_seeds(
        runner,
        [[jax.random.PRNGKey(s) for s in SEEDS]
         for _ in range(num_scenarios)],
        grid_splits,
        [[_ext() for _ in SEEDS] for _ in range(num_scenarios)],
        [[_SSL for _ in SEEDS] for _ in range(num_scenarios)],
        cfg, **kw)


def _run_loop(runner, grid_splits, cfg=_FAST, faults=None):
    return [run_seeds(runner, [jax.random.PRNGKey(s) for s in SEEDS], sp,
                      [_ext() for _ in SEEDS], [_SSL for _ in SEEDS], cfg,
                      **({} if faults is None else {"faults": faults[c]}))
            for c, sp in enumerate(grid_splits)]


def _assert_ledgers_equal(a, b):
    assert a.total_bytes() == b.total_bytes()
    assert a.comm_times() == b.comm_times()
    assert a.by_tag() == b.by_tag()


def _assert_grid_matches_loop(folded, loop):
    for scen_folded, scen_loop in zip(folded, loop):
        for res, ref in zip(scen_folded, scen_loop):
            assert abs(float(res.metric) - float(ref.metric)) < 1e-5, \
                (float(res.metric), float(ref.metric))
            _assert_ledgers_equal(res.ledger, ref.ledger)
            for cb, cs in zip(res.clients, ref.clients):
                for lb, ls in zip(jax.tree_util.tree_leaves(cb.params),
                                  jax.tree_util.tree_leaves(cs.params)):
                    assert jnp.allclose(lb, ls, atol=1e-5), \
                        float(jnp.max(jnp.abs(lb - ls)))


# --------------------------------------------------- FaultSpec semantics
def test_fault_spec_validation():
    with pytest.raises(ValueError, match="kind"):
        FaultSpec("meteor")
    with pytest.raises(ValueError, match="stage"):
        FaultSpec("dropout", stage="mid_coffee")
    with pytest.raises(ValueError, match="retry_rounds"):
        FaultSpec("dropout", retry_rounds=0)
    with pytest.raises(ValueError, match="epoch_fraction"):
        FaultSpec("straggler", epoch_fraction=1.5)
    with pytest.raises(ValueError, match="dp_sigma"):
        FaultSpec("dp_upload", dp_sigma=-0.1)
    with pytest.raises(ValueError, match="party"):
        FaultSpec("dropout", party=-1)


def test_fault_spec_predicates():
    fa = FaultSpec("dropout", party=1, stage="post_ssl")
    # gone from its stage threshold onward, never before, never another party
    assert not fa.drops(1, POINT_UPLOAD1) and not fa.drops(1, POINT_SSL)
    assert fa.drops(1, POINT_ROUND2) and fa.drops(1, POINT_EVAL)
    assert not fa.drops(0, POINT_EVAL)
    assert not fa.skips_ssl(1)          # dropped AFTER its SSL ran
    assert FaultSpec("dropout", party=1, stage="pre_ssl").skips_ssl(1)
    assert FA_REP.skips_ssl(1) and not FA_REP.skips_ssl(0)
    assert not FA_REP.drops(1, POINT_EVAL)   # frozen, but still present
    assert fa.parties_survived(4) == 3
    for other in (FA_STRAG, FA_DP, FA_REP):
        assert other.parties_survived(4) == 4
    for stage, frac in ITERATIVE_DROP_FRACTION.items():
        drop = FaultSpec("dropout", stage=stage)
        assert drop.iterative_active_steps(200) == int(frac * 200)
    assert FA_STRAG.iterative_active_steps(200) == 200


# ------------------------------------------------------------ fold parity
def test_faulted_seed_fold_matches_single_runs(grid_splits):
    """S=2 faulted ``run_seeds`` == the two unfolded ``run_one_shot``
    calls: per-seed metric at 1e-5, byte-identical ledgers (including the
    dropped party's SKIPPED upload events), matching fault diagnostics."""
    splits = grid_splits[0]
    faults = [FA_DROP, FA_DP]
    folded = run_seeds(run_one_shot, [jax.random.PRNGKey(s) for s in SEEDS],
                       splits, [_ext() for _ in SEEDS],
                       [_SSL for _ in SEEDS], _FAST, faults=faults)
    for s, res in enumerate(folded):
        ref = run_one_shot(jax.random.PRNGKey(SEEDS[s]), splits[s], _ext(),
                           _SSL, _FAST, fault=faults[s])
        assert abs(float(res.metric) - float(ref.metric)) < 1e-5
        _assert_ledgers_equal(res.ledger, ref.ledger)
        assert res.diagnostics["fault_kind"] == faults[s].kind
        assert res.diagnostics["parties_survived"] == \
            faults[s].parties_survived(2)
    # the dropped party's uploads never hit the wire; the DP party's do
    drop_tags = folded[0].ledger.by_tag()
    dp_tags = folded[1].ledger.by_tag()
    assert drop_tags != dp_tags
    assert folded[0].ledger.total_bytes() < folded[1].ledger.total_bytes()


def test_faulted_scenario_fold_matches_loop_one_shot(grid_splits):
    folded = _run_grid(run_one_shot, grid_splits, faults=_FAULTS)
    loop = _run_loop(run_one_shot, grid_splits, faults=_FAULTS)
    _assert_grid_matches_loop(folded, loop)
    flat = [r for scen in folded for r in scen]
    for r, fa in zip(flat, [fa for row in _FAULTS for fa in row]):
        assert r.diagnostics["seed_fold"] == len(SEEDS)
        assert r.diagnostics["fault_kind"] == \
            ("none" if fa is None else fa.kind)
        assert r.diagnostics["degraded_metric"] == pytest.approx(
            float(r.metric))


def test_faulted_scenario_fold_matches_loop_few_shot(grid_splits):
    """Same parity through round 2: the dropped/frozen party's zeroed
    ①' bundle, the Eq. 10 reconstruction at ⑥', and the skipped ⑤'
    sessions must all fold without feeling their healthy neighbors."""
    folded = _run_grid(run_few_shot, grid_splits, faults=_FAULTS)
    loop = _run_loop(run_few_shot, grid_splits, faults=_FAULTS)
    _assert_grid_matches_loop(folded, loop)


def test_faulted_scenario_fold_matches_loop_iterative(grid_splits):
    """The §11 scan fold with per-entry dropout truncation: entries
    stalling at DIFFERENT round counts (pre_upload vs post_ssl) share one
    stacked carry, and the retry-inflated ledgers come out byte-identical
    to the per-scenario loop's."""
    icfg = IterativeConfig(iterations=8)
    faults = [[FaultSpec("dropout", party=1, stage="pre_upload"), None],
              [FaultSpec("dropout", party=0, stage="post_ssl"), FA_STRAG]]
    folded = _run_grid(run_vanilla, grid_splits, cfg=icfg, faults=faults)
    loop = _run_loop(run_vanilla, grid_splits, cfg=icfg, faults=faults)
    _assert_grid_matches_loop(folded, loop)


def test_all_none_fault_grid_is_the_fault_free_path(grid_splits):
    """``faults=[None, None]`` must be indistinguishable from omitting the
    kwarg entirely — same metric, same prototype-ledger bytes. The healthy
    path pays nothing for the fault plumbing."""
    splits = grid_splits[0]
    plain = run_seeds(run_one_shot, [jax.random.PRNGKey(s) for s in SEEDS],
                      splits, [_ext() for _ in SEEDS],
                      [_SSL for _ in SEEDS], _FAST)
    nones = run_seeds(run_one_shot, [jax.random.PRNGKey(s) for s in SEEDS],
                      splits, [_ext() for _ in SEEDS],
                      [_SSL for _ in SEEDS], _FAST,
                      faults=[None] * len(SEEDS))
    for res, ref in zip(nones, plain):
        assert float(res.metric) == float(ref.metric)
        _assert_ledgers_equal(res.ledger, ref.ledger)
        assert "fault_kind" not in res.diagnostics


def test_changing_faults_adds_zero_fresh_session_misses(grid_splits):
    """Faults are data, not structure: after a warm faulted fold, a sweep
    with a DIFFERENT fault assignment (other kind, other party, other
    stage — same shapes) adds ZERO fresh session-cache misses in any
    domain. The §16 contract that lets a mixed-fault family share one
    group's compiled programs."""
    engine.clear_session_cache()
    _run_grid(run_one_shot, grid_splits, faults=_FAULTS)
    warm = {d: st["misses"]
            for d, st in engine.session_cache_stats_by_domain().items()}
    flipped = [[FA_STRAG, FA_DP],
               [FaultSpec("dropout", party=0, stage="post_ssl"), None]]
    _run_grid(run_one_shot, grid_splits, faults=flipped)
    after = {d: st["misses"]
             for d, st in engine.session_cache_stats_by_domain().items()}
    assert after == warm, (warm, after)


# ------------------------------------------------- iterative fault model
def test_iterative_dropout_charges_retry_rounds():
    split = _scenario_splits(0)[0]
    fa = FaultSpec("dropout", party=1, stage="pre_ssl", retry_rounds=2)
    icfg = IterativeConfig(iterations=8)
    res = run_vanilla(jax.random.PRNGKey(0), split, _ext(), _SSL, icfg,
                      fault=fa)
    ref = run_vanilla(jax.random.PRNGKey(0), split, _ext(), _SSL, icfg)
    tags = res.ledger.by_tag()
    # survivors re-send, the server probes the dead party — all in-ledger
    retry_cnt, retry_bytes = tags["retry_reps"]
    probe_cnt, probe_bytes = tags["retry_timeout"]
    assert retry_cnt == fa.retry_rounds          # one survivor x 2 rounds
    assert probe_cnt == fa.retry_rounds and probe_bytes == 4 * fa.retry_rounds
    d = res.diagnostics
    assert d["fault_modeled"] is True
    assert d["fault_retry_rounds"] == fa.retry_rounds
    assert d["fault_retry_bytes"] == retry_bytes + probe_bytes
    assert d["parties_survived"] == 1 and d["fault_kind"] == "dropout"
    # the stalled loop moved FEWER bytes than the full run, retries included
    assert res.ledger.total_bytes() < ref.ledger.total_bytes()
    assert "retry_reps" not in ref.ledger.by_tag()


def test_iterative_unmodeled_kinds_run_fault_free_and_say_so():
    """Straggler/DP/rep-only have no iterative model: the run must be
    byte-identical to fault-free and honestly flagged unmodeled — never a
    silent pretend-degradation."""
    split = _scenario_splits(0)[0]
    icfg = IterativeConfig(iterations=8)
    ref = run_vanilla(jax.random.PRNGKey(0), split, _ext(), _SSL, icfg)
    res = run_vanilla(jax.random.PRNGKey(0), split, _ext(), _SSL, icfg,
                      fault=FA_STRAG)
    assert float(res.metric) == float(ref.metric)
    _assert_ledgers_equal(res.ledger, ref.ledger)
    assert res.diagnostics["fault_modeled"] is False
    assert res.diagnostics["parties_survived"] == 2


def test_few_shot_finetune_refuses_faults(grid_splits):
    with pytest.raises(ValueError, match="does not support fault"):
        _few_shot_finetune_seeds(
            [jax.random.PRNGKey(0)], grid_splits[0][:1], [_ext()], [_SSL],
            _FAST, faults=[FA_DROP])


# ------------------------------------------------------- gate fault floors
_GATE_BASELINE = {
    "fault_families": {
        "fault": {
            "baseline_scenario": "fault/none",
            "max_oneshot_drop": 0.05,
            "required": ["fault/none", "fault/drop", "fault/strag"],
        },
    },
}

#: scenario -> (fault_kind, parties_survived of 4)
_GATE_SCENARIOS = {"fault/none": ("none", 4),
                   "fault/drop": ("dropout", 3),
                   "fault/strag": ("straggler", 4)}
_GMETRIC = {"one_shot": 0.90, "few_shot": 0.91,
            "iterative": 0.85, "fedcvt": 0.86}
_GBYTES = {"one_shot": 12288, "few_shot": 20480,
           "iterative": 12288 * 200, "fedcvt": 12288 * 220}


def _frow(method, seed, scenario, **over):
    kind, survived = _GATE_SCENARIOS[scenario]
    row = {
        "scenario": scenario,
        "seed": seed,
        "method": method,
        "metric_name": "accuracy",
        "metric": _GMETRIC[method],
        "comm_bytes": _GBYTES[method],
        "comm_times": 3,
        "overlap": 32,
        "num_parties": 4,
        "modality": "tabular",
        "fault_kind": kind,
        "parties_survived": survived,
    }
    if kind == "dropout":
        row["fault_stage"] = "pre_ssl"
        if method in ("iterative", "fedcvt"):
            row["fault_retry_rounds"] = 3
            row["fault_retry_bytes"] = 18444
    if method in ("one_shot", "few_shot"):
        row["degraded_metric"] = row["metric"]
    row.update(over)
    return row


def _fault_green_rows():
    return [_frow(m, s, scenario)
            for scenario in _GATE_SCENARIOS
            for m in frontier.METHODS for s in SEEDS]


@pytest.fixture
def fault_baseline_path(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps(_GATE_BASELINE))
    return str(p)


@pytest.fixture
def no_engine_env(monkeypatch):
    # fold/engine-path discipline is the vmap leg's concern
    # (test_frontier_gate.py) — these tests isolate the fault floors
    monkeypatch.delenv("REPRO_ENGINE_MODE", raising=False)


def test_fault_gate_green(fault_baseline_path, no_engine_env):
    assert frontier.check_gate(_fault_green_rows(), fault_baseline_path,
                               expect_faults=True) == []


def test_zero_fault_rows_violate_only_in_full_sweeps(fault_baseline_path,
                                                     no_engine_env):
    plain = [{k: v for k, v in r.items()
              if k not in ("fault_kind", "parties_survived", "fault_stage",
                           "degraded_metric", "fault_retry_rounds",
                           "fault_retry_bytes")}
             for r in _fault_green_rows()]
    problems = frontier.check_gate(plain, fault_baseline_path,
                                   expect_faults=True)
    assert any("no fault-injected rows" in p for p in problems)
    # an explicit --scenarios selection is a partial sweep by construction
    assert frontier.check_gate(plain, fault_baseline_path,
                               expect_faults=False) == []


def test_missing_family_member_violates(fault_baseline_path, no_engine_env):
    rows = [r for r in _fault_green_rows()
            if r["scenario"] != "fault/strag"]
    problems = frontier.check_gate(rows, fault_baseline_path,
                                   expect_faults=True)
    assert any("fault/strag" in p and "whole family" in p for p in problems)


def test_dropout_survivor_count_violates(fault_baseline_path, no_engine_env):
    rows = _fault_green_rows()
    for r in rows:
        if r["scenario"] == "fault/drop" and r["method"] == "one_shot":
            r["parties_survived"] = 4          # nobody actually dropped
    problems = frontier.check_gate(rows, fault_baseline_path,
                                   expect_faults=True)
    assert any("parties_survived=4" in p and "expected 3" in p
               for p in problems)
    # ...and a NON-dropout fault must not lose anyone
    rows = _fault_green_rows()
    for r in rows:
        if r["scenario"] == "fault/strag" and r["method"] == "few_shot":
            r["parties_survived"] = 3
    problems = frontier.check_gate(rows, fault_baseline_path,
                                   expect_faults=True)
    assert any("straggler" in p and "expected 4" in p for p in problems)


def test_iterative_dropout_without_retry_cost_violates(fault_baseline_path,
                                                       no_engine_env):
    rows = _fault_green_rows()
    for r in rows:
        if r["scenario"] == "fault/drop" and r["method"] == "iterative":
            r["fault_retry_rounds"] = 0
            r["fault_retry_bytes"] = 0
    problems = frontier.check_gate(rows, fault_baseline_path,
                                   expect_faults=True)
    assert any("no retry/timeout cost" in p for p in problems)


def test_oneshot_degradation_floor_violates(fault_baseline_path,
                                            no_engine_env):
    rows = _fault_green_rows()
    for r in rows:
        if r["scenario"] == "fault/drop" and r["method"] == "one_shot":
            r["metric"] = _GMETRIC["one_shot"] - 0.06   # beyond 0.05 budget
            r["degraded_metric"] = r["metric"]
    problems = frontier.check_gate(rows, fault_baseline_path,
                                   expect_faults=True)
    assert any("graceful degradation broke" in p for p in problems)


def test_missing_twin_and_missing_degraded_metric_violate(
        fault_baseline_path, no_engine_env):
    rows = [r for r in _fault_green_rows()
            if not (r["scenario"] == "fault/none"
                    and r["method"] == "one_shot")]
    problems = frontier.check_gate(rows, fault_baseline_path,
                                   expect_faults=True)
    assert any("no one_shot rows to measure degradation" in p
               for p in problems)
    rows = _fault_green_rows()
    for r in rows:
        if r["scenario"] == "fault/drop" and r["method"] == "few_shot":
            r.pop("degraded_metric")
    problems = frontier.check_gate(rows, fault_baseline_path,
                                   expect_faults=True)
    assert any("no degraded_metric" in p for p in problems)
