"""HLO analyzer: scan-aware FLOP/collective extraction correctness."""
import jax
import jax.numpy as jnp
import pytest

from repro.roofline.analysis import active_params, model_flops, roofline_terms
from repro.roofline.hlo_analysis import analyze_hlo_text


def test_scan_flops_exact():
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    ws = jax.ShapeDtypeStruct((7, 256, 256), jnp.float32)
    cost = analyze_hlo_text(jax.jit(f).lower(x, ws).compile().as_text())
    assert cost.dot_flops == 7 * 2 * 256 ** 3
    assert cost.while_trip_counts == [7]


def test_nested_scan_flops():
    def f(x, ws):
        def outer(c, w):
            def inner(ci, _):
                return ci @ w, ()
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return ci, ()
        y, _ = jax.lax.scan(outer, x, ws)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    ws = jax.ShapeDtypeStruct((5, 128, 128), jnp.float32)
    cost = analyze_hlo_text(jax.jit(f).lower(x, ws).compile().as_text())
    assert cost.dot_flops == 5 * 3 * 2 * 128 ** 3


def test_unscanned_matmul_flops():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((32, 48), jnp.float32)
    cost = analyze_hlo_text(jax.jit(f).lower(a, b).compile().as_text())
    assert cost.dot_flops == 2 * 64 * 32 * 48


def test_roofline_terms_bottleneck():
    t = roofline_terms({"dot_flops": 197e12, "traffic_bytes": 1e9,
                        "collective_bytes": 0})
    assert t["bottleneck"] == "compute"
    assert t["compute_s"] == pytest.approx(1.0)
    t2 = roofline_terms({"dot_flops": 1e9, "traffic_bytes": 819e9,
                         "collective_bytes": 0})
    assert t2["bottleneck"] == "memory"


def test_active_params_dense_plausible():
    from repro.configs import get_config
    n = active_params(get_config("llama3-405b"))
    assert 3.8e11 < n < 4.4e11      # ~405B

    n_moe = active_params(get_config("granite-moe-3b-a800m"))
    assert n_moe < 1.5e9            # active ≪ total for MoE


def test_model_flops_train_vs_decode():
    from repro.configs import INPUT_SHAPES, get_config
    cfg = get_config("gemma-7b")
    tr = model_flops(cfg, INPUT_SHAPES["train_4k"])
    de = model_flops(cfg, INPUT_SHAPES["decode_32k"])
    assert tr > de * 1000           # train step ≫ one decode token
