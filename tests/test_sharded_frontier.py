"""Device-parallel frontier (DESIGN.md §14): the stacked S·C·K axis shards
over the launch mesh and must be indistinguishable from the single-device
fold:

* shard ≡ single-device parity at 1e-5 on the metric AND every client
  parameter leaf — for one-shot, few-shot, and the iterative scan fold —
  with byte-identical ledgers (logged host-side from real entries only);
* the padding rule: a stacked axis not divisible by the device count pads
  with dummy entries device-side and strips them host-side, so a 3-entry
  batch on a 2-device mesh matches the unsharded run exactly;
* mesh-keyed cache discipline: mesh identity (axis names + shape) IS part
  of every session key — the first sharded run takes one mesh-keyed miss
  per session kind, after which re-running at ANY batch width (sharded or
  single-device) adds ZERO fresh builds;
* ``device_fold`` diagnostics record the width the heavy stage actually
  folded over (mesh size on the folded paths, 1 otherwise).

This module needs >= 2 visible devices. It forces 8 host devices via
``launch.mesh.forced_host_devices`` — which only works when the jax
backend has not yet initialized, i.e. when the module runs in its own
process (the CI multi-device leg sets ``XLA_FLAGS`` instead). Inside a
full tier-1 run another module usually wins backend init first, and this
one skips.
"""
import copy

from repro.launch.mesh import forced_host_devices

forced_host_devices(8)

import jax                     # noqa: E402
import jax.numpy as jnp        # noqa: E402
import pytest                  # noqa: E402

if jax.device_count() < 2:
    pytest.skip("needs >= 2 devices (run with "
                "XLA_FLAGS=--xla_force_host_platform_device_count=8, or as "
                "its own process)", allow_module_level=True)

from repro import engine                                      # noqa: E402
from repro.core import (IterativeConfig, ProtocolConfig,      # noqa: E402
                        SSLConfig, run_few_shot, run_one_shot,
                        run_vanilla)
from repro.core.protocol import run_seeds                     # noqa: E402
from repro.data import (make_tabular_credit,                  # noqa: E402
                        make_vfl_partition)
from repro.models import make_mlp_extractor                   # noqa: E402

# the module tests the FOLDED paths (only they have a stacked axis to
# shard), so pin the engine modes rather than inherit the CI matrix knob —
# under REPRO_ENGINE_MODE=python these would otherwise resolve to the
# per-client/per-step loops, where mesh is (correctly) ignored
_FAST = ProtocolConfig(client_epochs=2, server_epochs=3, engine_mode="vmap")
_ITER = IterativeConfig(iterations=60, eval_every=30, engine_mode="scan")
_SSL = [SSLConfig(modality="tabular")] * 2


def _ext():
    return [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]


def _splits(seeds, overlap=48):
    x, y = make_tabular_credit(jax.random.PRNGKey(5000), 700)
    return [make_vfl_partition(x[:, :22], y, overlap_size=overlap,
                               feature_sizes=[11, 11], seed=s)
            for s in seeds]


def _run(runner, seeds, cfg, splits=None):
    splits = _splits(seeds) if splits is None else splits
    return run_seeds(runner, [jax.random.PRNGKey(s) for s in seeds], splits,
                     [_ext() for _ in seeds], [_SSL for _ in seeds], cfg)


def _assert_parity(sharded, single):
    for a, b in zip(sharded, single):
        assert abs(float(a.metric) - float(b.metric)) < 1e-5, \
            (float(a.metric), float(b.metric))
        assert a.ledger.total_bytes() == b.ledger.total_bytes()
        assert a.ledger.comm_times() == b.ledger.comm_times()
        assert a.ledger.by_tag() == b.ledger.by_tag()
        for ca, cb in zip(a.clients, b.clients):
            for la, lb in zip(jax.tree_util.tree_leaves(ca.params),
                              jax.tree_util.tree_leaves(cb.params)):
                assert jnp.allclose(la, lb, atol=1e-5), \
                    float(jnp.max(jnp.abs(la - lb)))


@pytest.mark.parametrize("runner,cfg", [
    (run_one_shot, _FAST),
    (run_few_shot, _FAST),
    (run_vanilla, _ITER),
], ids=["one_shot", "few_shot", "vanilla"])
def test_sharded_matches_single_device(runner, cfg):
    """The tentpole parity: a 2-device mesh over S=2 seeds reproduces the
    single-device fold at 1e-5 on metric and every parameter leaf, with
    byte-identical ledgers (communication is logged host-side from the
    real entries — dummy padding rows never reach the ledger)."""
    seeds = (0, 1)
    single = _run(runner, seeds, cfg)
    import dataclasses
    sharded = _run(runner, seeds, dataclasses.replace(cfg, mesh=2))
    _assert_parity(sharded, single)
    for r in single:
        assert r.diagnostics["device_fold"] == 1
    for r in sharded:
        assert r.diagnostics["device_fold"] == 2


@pytest.mark.parametrize("devices", [2, 4],
                         ids=["pad-3-to-4", "pad-3x2-to-8"])
def test_non_divisible_batch_pads_and_strips(devices):
    """3 seeds on a 2-device mesh (stacked width 3 → padded 4) and on a
    4-device mesh (the SSL stack's S·K = 6 → padded 8): dummy entries are
    repeats of entry 0, stripped host-side, and must not perturb any real
    entry — parity holds entry by entry."""
    import dataclasses
    seeds = (0, 1, 2)
    for runner, cfg in ((run_one_shot, _FAST), (run_vanilla, _ITER)):
        single = _run(runner, seeds, cfg)
        sharded = _run(runner, seeds,
                       dataclasses.replace(cfg, mesh=devices))
        _assert_parity(sharded, single)
        for r in sharded:
            assert r.diagnostics["device_fold"] == devices


def test_mesh_keyed_cache_discipline():
    """Mesh identity is part of every session key: against a warm
    single-device cache the FIRST sharded run takes fresh mesh-keyed
    misses, after which (a) a sharded re-run at a DIFFERENT batch width
    and (b) a single-device re-run both add ZERO fresh builds — the keys
    carry the mesh but never the batch width."""
    import dataclasses
    engine.clear_session_cache()
    _run(run_one_shot, (0, 1), _FAST)
    warm = copy.deepcopy(engine.session_cache_stats_by_domain())

    sharded_cfg = dataclasses.replace(_FAST, mesh=2)
    _run(run_one_shot, (0, 1), sharded_cfg)
    first = copy.deepcopy(engine.session_cache_stats_by_domain())
    fresh = {d: first[d]["misses"] - warm.get(d, {"misses": 0})["misses"]
             for d in first}
    assert any(v > 0 for v in fresh.values()), fresh   # mesh IS in the key

    _run(run_one_shot, (0, 1, 2), sharded_cfg)         # new width, same mesh
    second = engine.session_cache_stats_by_domain()
    for d in second:
        assert second[d]["misses"] == first[d]["misses"], (d, first, second)

    _run(run_one_shot, (0, 1), _FAST)                  # single-device again
    third = engine.session_cache_stats_by_domain()
    for d in third:
        assert third[d]["misses"] == second[d]["misses"], (d, second, third)


def test_device_fold_diagnostic_pins():
    """``device_fold`` records the width the heavy stage actually folded:
    the mesh size on the folded engine paths, 1 on the Python fallback
    (where no stacked axis exists to shard)."""
    import dataclasses
    seeds = (0, 1)
    sharded = _run(run_vanilla, seeds, dataclasses.replace(_ITER, mesh=2))
    for r in sharded:
        assert r.diagnostics["engine_path"] == "scan"
        assert r.diagnostics["device_fold"] == 2
    python_cfg = dataclasses.replace(_ITER, mesh=2, engine_mode="python")
    looped = _run(run_vanilla, seeds, python_cfg)
    for r in looped:
        assert r.diagnostics["engine_path"] == "python"
        assert r.diagnostics["device_fold"] == 1
