import jax
import jax.numpy as jnp
import pytest

from repro.core.estimator import (estimate_missing_parties, infer_prob,
                                  sdpa_transform)
from repro.core.ssl import SSLConfig, ssl_loss


# ------------------------------------------------------------ SSL loss -----
def _linear_logits(params, x):
    return x @ params["w"]


def test_ssl_loss_components():
    key = jax.random.PRNGKey(0)
    params = {"w": 0.1 * jax.random.normal(key, (23, 4))}
    cfg = SSLConfig(modality="tabular", lambda_u=1.0, confidence_threshold=0.0)
    xl = jax.random.normal(jax.random.PRNGKey(1), (16, 23))
    yl = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 4)
    xu = jax.random.normal(jax.random.PRNGKey(3), (32, 23))
    loss, metrics = ssl_loss(_linear_logits, params, jax.random.PRNGKey(4),
                             xl, yl, xu, cfg, feature_mean=jnp.zeros(23))
    assert float(loss) > 0
    assert metrics["pseudo_mask_rate"] == 1.0   # threshold 0 → all pass
    assert float(metrics["l_s"]) > 0 and float(metrics["l_u"]) >= 0


def test_ssl_threshold_gates_unsupervised():
    params = {"w": 1e-4 * jnp.ones((23, 4))}   # near-uniform predictions
    cfg = SSLConfig(modality="tabular", confidence_threshold=0.99)
    xl = jnp.ones((8, 23))
    yl = jnp.zeros((8,), jnp.int32)
    xu = jnp.ones((8, 23))
    loss, metrics = ssl_loss(_linear_logits, params, jax.random.PRNGKey(0),
                             xl, yl, xu, cfg, feature_mean=jnp.zeros(23))
    assert float(metrics["pseudo_mask_rate"]) == 0.0
    assert float(metrics["l_u"]) == 0.0


def test_ssl_training_reduces_loss():
    key = jax.random.PRNGKey(0)
    w_true = jax.random.normal(key, (10, 3))
    x = jax.random.normal(jax.random.PRNGKey(1), (128, 10))
    y = jnp.argmax(x @ w_true, axis=-1)
    params = {"w": jnp.zeros((10, 3))}
    cfg = SSLConfig(modality="tabular", lambda_u=0.5, confidence_threshold=0.8)
    fm = x.mean(0)
    losses = []
    for i in range(60):
        def lf(p):
            return ssl_loss(_linear_logits, p, jax.random.PRNGKey(i),
                            x[:64], y[:64], x[64:], cfg, feature_mean=fm)[0]
        g = jax.grad(lf)(params)
        params = {"w": params["w"] - 0.5 * g["w"]}
        losses.append(float(lf(params)))
    assert losses[-1] < losses[0] * 0.7


# --------------------------------------------------------- SDPA (Eq. 10) ---
def test_sdpa_transform_matches_manual():
    k = jax.random.PRNGKey(0)
    hu = jax.random.normal(k, (7, 8))
    hoa = jax.random.normal(jax.random.PRNGKey(1), (5, 8))
    hob = jax.random.normal(jax.random.PRNGKey(2), (5, 12))
    got = sdpa_transform(hu, hoa, hob)
    w = jax.nn.softmax(hu @ hoa.T / jnp.sqrt(8.0), axis=-1)
    assert jnp.allclose(got, w @ hob, atol=1e-5)
    assert got.shape == (7, 12)


def test_sdpa_kernel_path_matches():
    hu = jax.random.normal(jax.random.PRNGKey(0), (33, 16))
    hoa = jax.random.normal(jax.random.PRNGKey(1), (21, 16))
    hob = jax.random.normal(jax.random.PRNGKey(2), (21, 24))
    a = sdpa_transform(hu, hoa, hob, use_kernel=False)
    b = sdpa_transform(hu, hoa, hob, use_kernel=True)
    assert jnp.allclose(a, b, atol=1e-4)


def test_sdpa_rows_are_convex_combinations():
    """Each estimated rep is a weighted average of overlap reps — it must lie
    inside their bounding box."""
    hu = jax.random.normal(jax.random.PRNGKey(0), (50, 6))
    hoa = jax.random.normal(jax.random.PRNGKey(1), (9, 6))
    hob = jax.random.normal(jax.random.PRNGKey(2), (9, 4))
    est = sdpa_transform(hu, hoa, hob)
    assert float(est.max()) <= float(hob.max()) + 1e-5
    assert float(est.min()) >= float(hob.min()) - 1e-5


def test_estimate_missing_parties_k3():
    h = [jax.random.normal(jax.random.PRNGKey(i), (6, 8)) for i in range(3)]
    hu = jax.random.normal(jax.random.PRNGKey(9), (11, 8))
    est = estimate_missing_parties(hu, h, k=1)
    assert len(est) == 2
    assert est[0].shape == (11, 8) and est[1].shape == (11, 8)


# ----------------------------------------------------- infer_prob (Eq. 9) --
def test_infer_prob_agreement_and_threshold():
    n, c = 6, 3
    strong = 50.0
    local_logits = jnp.eye(c)[jnp.array([0, 0, 1, 2, 2, 1])] * strong
    joint_logits = jnp.eye(c)[jnp.array([0, 1, 1, 2, 0, 1])] * strong
    p = infer_prob(lambda h: local_logits, lambda h: joint_logits,
                   jnp.zeros((n, 4)), jnp.zeros((n, 8)), threshold=0.9)
    agree = jnp.array([1, 0, 1, 1, 0, 1])
    assert jnp.allclose((p > 0).astype(jnp.int32), agree)
    # p equals joint confidence where gated on
    assert float(p[0]) == pytest.approx(float(jax.nn.softmax(joint_logits[0])[0]), rel=1e-5)


def test_infer_prob_low_confidence_zero():
    n, c = 4, 3
    logits = jnp.zeros((n, c))    # uniform → max prob 1/3 < 0.9
    p = infer_prob(lambda h: logits, lambda h: logits,
                   jnp.zeros((n, 4)), jnp.zeros((n, 8)), threshold=0.9)
    assert jnp.allclose(p, 0.0)
