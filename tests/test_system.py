"""End-to-end behaviour tests for the paper's system.

The headline claims, scaled to CPU-sized synthetics:
  1. one-shot VFL = exactly 3 comm times; few-shot = 5;
  2. one-shot bytes ≪ vanilla bytes (the 330×-class reduction is mechanical
     in the ledger once iteration counts reach paper scale);
  3. gradient clustering gives useful pseudo-labels (purity ≫ chance);
  4. the image pipeline (CNN extractors, halved images) runs end to end.
"""
import jax

from repro.core import ProtocolConfig, SSLConfig, run_one_shot
from repro.data import (make_image_classification, make_tabular_credit,
                        make_vfl_partition)
from repro.models import make_cnn_extractor, make_mlp_extractor


def test_image_vfl_one_shot_end_to_end():
    """The paper's CIFAR-10 protocol shape: images split into halves, CNN
    extractors, k-means on partial gradients, FixMatch SSL."""
    x, y = make_image_classification(jax.random.PRNGKey(0), 500,
                                     num_classes=4, image_size=16,
                                     template_strength=3.0)
    split = make_vfl_partition(x, y, overlap_size=96, seed=1, num_classes=4)
    assert split.aligned[0].shape == (96, 16, 8, 3)

    ext = [make_cnn_extractor(rep_dim=32, widths=(8, 16), blocks_per_stage=1)
           for _ in range(2)]
    cfgs = [SSLConfig(modality="image", max_shift=2, cutout_size=4)] * 2
    res = run_one_shot(jax.random.PRNGKey(1), split, ext, cfgs,
                       ProtocolConfig(client_epochs=3, server_epochs=10))
    assert res.metric_name == "accuracy"
    assert res.metric > 0.28                     # > 0.25 chance
    assert res.ledger.comm_times() == 3
    assert res.diagnostics["kmeans_purity"][0] > 0.5


def test_comm_reduction_ratio_at_paper_scale():
    """Mechanical check of Tab. 1 accounting: at the paper's CIFAR-10 scale
    (N_o=2048, B=32, 64000 iterations, rep_dim 128) vanilla VFL moves ~2 GB
    while one-shot moves ~6 MB — a ≥330× reduction."""
    from repro.core.comm import CommLedger

    rep_dim, B = 128, 32
    vanilla = CommLedger()
    for it in range(64000):
        r1, r2 = vanilla.next_round(), vanilla.next_round()
        for c in range(2):
            vanilla.log_bytes(c, "up", "reps", B * rep_dim * 4, round=r1)
            vanilla.log_bytes(c, "down", "grads", B * rep_dim * 4, round=r2)

    one = CommLedger()
    n_o = 2048
    r1, r2, r3 = one.next_round(), one.next_round(), one.next_round()
    for c in range(2):
        one.log_bytes(c, "up", "reps", n_o * rep_dim * 4, round=r1)
        one.log_bytes(c, "down", "grads", n_o * rep_dim * 4, round=r2)
        one.log_bytes(c, "up", "reps2", n_o * rep_dim * 4, round=r3)

    ratio = vanilla.total_bytes() / one.total_bytes()
    assert ratio > 330
    assert one.comm_times() == 3
    assert vanilla.comm_times() == 128000


def test_tabular_auc_beats_chance_with_tiny_overlap():
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 1500)
    split = make_vfl_partition(x, y, overlap_size=64, feature_sizes=[10, 13],
                               seed=2)
    ext = [make_mlp_extractor(rep_dim=16, hidden=(32,)) for _ in range(2)]
    res = run_one_shot(jax.random.PRNGKey(1), split, ext,
                       [SSLConfig(modality="tabular")] * 2,
                       ProtocolConfig(client_epochs=3, server_epochs=8))
    assert res.metric > 0.6
