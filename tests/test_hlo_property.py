"""Property tests: the scan-aware HLO analyzer must recover exact dot FLOPs
for arbitrary compositions of matmuls, scans and nested scans."""
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.roofline.hlo_analysis import analyze_hlo_text


def _flops_of(fn, *args):
    comp = jax.jit(fn).lower(*args).compile()
    return analyze_hlo_text(comp.as_text()).dot_flops


@settings(max_examples=8, deadline=None)
@given(trips=st.integers(1, 12), m=st.sampled_from([64, 128, 256]))
def test_scan_matmul_flops_exact(trips, m):
    def f(x, ws):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((trips, m, m), jnp.float32)
    assert _flops_of(f, x, ws) == trips * 2 * m ** 3


@settings(max_examples=5, deadline=None)
@given(outer=st.integers(1, 5), inner=st.integers(1, 5))
def test_nested_scan_flops_exact(outer, inner):
    m = 64

    def f(x, ws):
        def o_body(c, w):
            def i_body(ci, _):
                return ci @ w, ()
            ci, _ = jax.lax.scan(i_body, c, None, length=inner)
            return ci, ()
        y, _ = jax.lax.scan(o_body, x, ws)
        return y

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((outer, m, m), jnp.float32)
    assert _flops_of(f, x, ws) == outer * inner * 2 * m ** 3


def test_mixed_scan_plus_outside_matmul():
    m = 128

    def f(x, ws, a):
        def body(c, w):
            return c @ w, ()
        y, _ = jax.lax.scan(body, x, ws)
        return y @ a

    x = jax.ShapeDtypeStruct((m, m), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, m, m), jnp.float32)
    a = jax.ShapeDtypeStruct((m, 2 * m), jnp.float32)
    got = _flops_of(f, x, ws, a)
    assert got == 3 * 2 * m ** 3 + 2 * m * m * 2 * m


def test_batched_dot_flops():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 32, 48), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 48, 16), jnp.float32)
    assert _flops_of(f, a, b) == 4 * 2 * 32 * 48 * 16


def test_traffic_positive_and_bounded():
    """Traffic estimate is an upper bound ≥ the unavoidable IO (inputs +
    outputs, once each)."""
    m = 256

    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((m, m), jnp.float32)
    b = jax.ShapeDtypeStruct((m, m), jnp.float32)
    comp = jax.jit(f).lower(a, b).compile()
    cost = analyze_hlo_text(comp.as_text())
    unavoidable = 3 * m * m * 4
    assert cost.traffic_bytes >= unavoidable
    assert cost.traffic_bytes <= 4 * unavoidable
