"""Unit tests for the frontier CI gate (``benchmarks.frontier.check_gate``)
on hand-built row blobs — so the gate's logic is exercised in tier-1, not
only when bench CI happens to run:

* a fully consistent blob stays green;
* missing few-shot rows violate (an unmeasured margin must not pass);
* ``seed_fold`` / ``scenario_fold`` mismatches violate under the vmap CI
  matrix leg (the folds must actually have run);
* engine-path, bytes-invariance, bytes-regression, and margin floors
  violate exactly when they should — and the dominance checks apply only
  to baseline-listed scenarios (the full smoke catalog's unlisted rows get
  invariance + fold discipline only).
"""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import frontier

SEEDS = (0, 1)
_BASELINE = {
    "hard/overlap-32": {
        "one_shot_bytes": 12288,
        "min_mean_margin": 0.01,
        "min_worst_margin": 0.0,
        "fewshot_min_mean_margin": 0.01,
        "fewshot_min_worst_margin": 0.0,
    },
}

_METRIC = {"one_shot": 0.92, "few_shot": 0.93,
           "iterative": 0.80, "fedcvt": 0.82}
_BYTES = {"one_shot": 12288, "few_shot": 20480,
          "iterative": 12288 * 200, "fedcvt": 12288 * 220}
_PATH = {"one_shot": "vmap", "few_shot": "vmap",
         "iterative": "scan", "fedcvt": "scan"}


def _row(method, seed, scenario="hard/overlap-32", **over):
    row = {
        "scenario": scenario,
        "seed": seed,
        "method": method,
        "metric_name": "accuracy",
        "metric": _METRIC[method],
        "comm_bytes": _BYTES[method],
        "comm_times": 3,
        "engine_path": _PATH[method],
        "seed_fold": len(SEEDS),
        "scenario_fold": 1,
        "group_size": 1,
        "vmap_eligible": True,
        "overlap": 32,
        "num_parties": 2,
        "modality": "tabular",
    }
    row.update(over)
    return row


def _green_rows(scenario="hard/overlap-32", **over):
    return [_row(m, s, scenario=scenario, **over)
            for m in frontier.METHODS for s in SEEDS]


@pytest.fixture
def baseline_path(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps(_BASELINE))
    return str(p)


@pytest.fixture
def vmap_env(monkeypatch):
    monkeypatch.setenv("REPRO_ENGINE_MODE", "vmap")


def test_green_blob_passes(baseline_path, vmap_env):
    assert frontier.check_gate(_green_rows(), baseline_path) == []


def test_aggregate_rows_are_ignored(baseline_path, vmap_env):
    rows = _green_rows()
    # a degenerate aggregate row must not feed the per-seed checks
    rows.append(_row("one_shot", "aggregate", aggregate=True,
                     engine_path="python", seed_fold=1, scenario_fold=0))
    assert frontier.check_gate(rows, baseline_path) == []


def test_missing_few_shot_rows_violate(baseline_path, vmap_env):
    rows = [r for r in _green_rows() if r["method"] != "few_shot"]
    problems = frontier.check_gate(rows, baseline_path)
    assert any("no few_shot rows" in p for p in problems)


def test_seed_fold_mismatch_violates(baseline_path, vmap_env):
    rows = _green_rows()
    rows[0] = dict(rows[0], seed_fold=1)
    problems = frontier.check_gate(rows, baseline_path)
    assert any("seed_fold=1" in p and "per-seed loop" in p
               for p in problems)


def test_scenario_fold_mismatch_violates(baseline_path, vmap_env):
    """A row recorded against a size-C group must have folded all C
    scenarios — the grouped sweep silently degrading to the per-scenario
    loop is exactly what this assert exists to catch."""
    rows = _green_rows(group_size=3, scenario_fold=3)
    assert frontier.check_gate(rows, baseline_path) == []
    rows[3] = dict(rows[3], scenario_fold=1)
    problems = frontier.check_gate(rows, baseline_path)
    assert any("scenario_fold=1" in p and "size-3 group" in p
               for p in problems)


def test_fold_checks_only_under_vmap_matrix_leg(baseline_path, monkeypatch):
    """Outside the forced-vmap CI leg the fold/engine-path discipline is
    not asserted (the python leg legitimately loops) — the dominance
    checks still are."""
    monkeypatch.delenv("REPRO_ENGINE_MODE", raising=False)
    rows = _green_rows(seed_fold=1, scenario_fold=1, group_size=3,
                       engine_path="python")
    assert frontier.check_gate(rows, baseline_path) == []


def test_engine_path_violations(baseline_path, vmap_env):
    rows = _green_rows()
    rows[0] = dict(rows[0], engine_path="python")          # one_shot, vmap-able
    rows[4] = dict(rows[4], engine_path="python")          # iterative
    problems = frontier.check_gate(rows, baseline_path)
    assert sum("engine_path='python'" in p for p in problems) == 2
    # heterogeneous party zoos are exempt from the protocol-path check
    rows = _green_rows(vmap_eligible=False)
    for r in rows:
        if r["method"] in ("one_shot", "few_shot"):
            r["engine_path"] = "python"
    assert frontier.check_gate(rows, baseline_path) == []


def test_bytes_invariance_and_regression(baseline_path, vmap_env):
    rows = _green_rows()
    rows[1] = dict(rows[1], comm_bytes=_BYTES["one_shot"] + 4)
    problems = frontier.check_gate(rows, baseline_path)
    assert any("seed-invariant" in p for p in problems)
    rows = _green_rows()
    for r in rows:
        if r["method"] == "one_shot":
            r["comm_bytes"] = _BASELINE["hard/overlap-32"]["one_shot_bytes"] + 8
    problems = frontier.check_gate(rows, baseline_path)
    assert any("regressed" in p for p in problems)


def test_margin_floors_violate(baseline_path, vmap_env):
    rows = _green_rows()
    for r in rows:
        if r["method"] == "one_shot":
            r["metric"] = _METRIC["iterative"] + 0.005   # below 0.01 floor
    problems = frontier.check_gate(rows, baseline_path)
    assert any("one-shot mean margin" in p for p in problems)
    rows = _green_rows()
    for r in rows:
        if r["method"] == "few_shot" and r["seed"] == SEEDS[1]:
            r["metric"] = _METRIC["iterative"] - 0.05    # one losing seed
    problems = frontier.check_gate(rows, baseline_path)
    assert any("few-shot worst-seed margin" in p for p in problems)


def test_bytes_ratio_violates(baseline_path, vmap_env):
    rows = _green_rows()
    for r in rows:
        if r["method"] == "iterative":
            r["comm_bytes"] = _BYTES["one_shot"] * 50    # < 100x advantage
    problems = frontier.check_gate(rows, baseline_path)
    assert any("< 100x" in p for p in problems)


def test_dominance_checks_scoped_to_baseline_listed_scenarios(
        baseline_path, vmap_env):
    """An unlisted low-overlap scenario (e.g. the smoke catalog's image
    rows, whose iteration budgets make no 100x claim) gets NO dominance
    checks — but keeps seed-invariance and fold discipline."""
    rows = _green_rows(scenario="image/halves")
    for r in rows:                 # would fail every dominance check...
        if r["method"] == "iterative":
            r["comm_bytes"] = _BYTES["one_shot"] * 2
        if r["method"] == "one_shot":
            r["metric"] = _METRIC["iterative"] - 0.1
    rows = [r for r in rows if r["method"] != "few_shot"]  # ...and this one
    assert frontier.check_gate(rows, baseline_path) == []
    # invariance still applies to unlisted scenarios
    rows[1] = dict(rows[1], comm_bytes=_BYTES["one_shot"] + 4)
    problems = frontier.check_gate(rows, baseline_path)
    assert any("seed-invariant" in p for p in problems)
