import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core.augment import (strong_augment_image, tab_augment_pair,
                                weak_augment_image, weak_augment_tab)


def test_weak_image_preserves_shape_and_range():
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 32, 32, 3))
    y = weak_augment_image(jax.random.PRNGKey(1), x)
    assert y.shape == x.shape
    # flips/translations don't change the value set much
    assert float(jnp.abs(y).max()) <= float(jnp.abs(x).max()) + 1e-5


def test_strong_image_differs_from_weak():
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 32, 32, 3))
    k = jax.random.PRNGKey(1)
    w = weak_augment_image(k, x)
    s = strong_augment_image(k, x)
    assert float(jnp.abs(w - s).mean()) > 0.01


@settings(max_examples=10, deadline=None)
@given(r_m=st.floats(0.05, 0.6), seed=st.integers(0, 1000))
def test_property_tab_mask_ratio(r_m, seed):
    """Eq. 5: mask elements ~ Bernoulli(r_m) — empirical rate within 5σ."""
    x = jnp.ones((64, 100)) * 7.0
    mean = jnp.zeros((100,))
    weak = weak_augment_tab(jax.random.PRNGKey(seed), x, mean, r_m)
    rate = float((weak == 0.0).mean())   # masked → replaced by mean=0
    sigma = (r_m * (1 - r_m) / 6400) ** 0.5
    assert abs(rate - r_m) < 5 * sigma + 1e-3


def test_tab_pair_shares_mask():
    """The paper samples ONE mask for both augmentations (Eq. 6):
    strong − weak must be pure Gaussian noise (no differing mask)."""
    x = jax.random.normal(jax.random.PRNGKey(0), (16, 23)) + 5.0
    mean = jnp.zeros((23,))
    weak, strong = tab_augment_pair(jax.random.PRNGKey(1), x, mean,
                                    mask_ratio=0.3, sigma=0.1)
    diff = strong - weak
    # noise is N(0, 0.1²): no structural (masking) differences
    assert float(jnp.abs(diff).max()) < 0.1 * 6
    assert float(diff.std()) == pytest.approx(0.1, rel=0.3)


def test_tab_weak_uses_feature_mean():
    x = jnp.ones((8, 4)) * 3.0
    mean = jnp.array([10.0, 20.0, 30.0, 40.0])
    weak = weak_augment_tab(jax.random.PRNGKey(0), x, mean, mask_ratio=0.9)
    vals = set(float(v) for v in jnp.unique(weak))
    assert vals <= {3.0, 10.0, 20.0, 30.0, 40.0}
