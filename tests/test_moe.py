import dataclasses

import jax
import jax.numpy as jnp
import pytest
hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.layers import init_params
from repro.models.moe import moe_apply, moe_shapes


def _cfg(capacity_factor=8.0, top_k=2, experts=4):
    base = get_config("granite-moe-3b-a800m").reduced()
    return dataclasses.replace(
        base, moe=dataclasses.replace(base.moe, capacity_factor=capacity_factor,
                                      top_k=top_k, num_experts=experts))


def test_moe_output_shape_and_finite():
    cfg = _cfg()
    params = init_params(jax.random.PRNGKey(0), moe_shapes(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    y, aux = moe_apply(params, x, cfg)
    assert y.shape == x.shape
    assert not bool(jnp.any(jnp.isnan(y)))
    assert float(aux) > 0


def test_moe_capacity_drops_tokens():
    """With a tiny capacity factor most tokens overflow → output ~ 0 for
    dropped tokens (residual passthrough happens outside)."""
    cfg_small = _cfg(capacity_factor=0.05)
    cfg_big = _cfg(capacity_factor=8.0)
    params = init_params(jax.random.PRNGKey(0), moe_shapes(cfg_small))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg_small.d_model))
    y_small, _ = moe_apply(params, x, cfg_small)
    y_big, _ = moe_apply(params, x, cfg_big)
    # dropping reduces output energy
    assert float(jnp.abs(y_small).mean()) < float(jnp.abs(y_big).mean())


def test_moe_decode_drop_free():
    """s==1 (decode) must be drop-free regardless of routing skew."""
    cfg = _cfg(capacity_factor=0.01)
    params = init_params(jax.random.PRNGKey(0), moe_shapes(cfg))
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 1, cfg.d_model))
    y, _ = moe_apply(params, x, cfg)
    # every token got expert output (no all-zero rows)
    norms = jnp.linalg.norm(y[:, 0, :], axis=-1)
    assert float(norms.min()) > 0


def test_moe_shared_expert_always_on():
    cfg = get_config("deepseek-v2-236b").reduced()
    params = init_params(jax.random.PRNGKey(0), moe_shapes(cfg))
    assert "shared" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 8, cfg.d_model))
    y, _ = moe_apply(params, x, cfg)
    assert y.shape == x.shape


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 50))
def test_property_moe_permutation_equivariance(seed):
    """Token order must not change per-token outputs (drop-free regime)."""
    cfg = _cfg(capacity_factor=16.0)
    params = init_params(jax.random.PRNGKey(0), moe_shapes(cfg))
    x = jax.random.normal(jax.random.PRNGKey(seed), (1, 16, cfg.d_model))
    perm = jax.random.permutation(jax.random.PRNGKey(seed + 1), 16)
    y, _ = moe_apply(params, x, cfg)
    y_perm, _ = moe_apply(params, x[:, perm], cfg)
    assert jnp.allclose(y[:, perm], y_perm, atol=1e-4)
