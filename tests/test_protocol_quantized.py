"""§Perf C (beyond-paper): bf16 representation exchange — half the bytes of
the paper's f32 accounting at indistinguishable utility."""
import jax
import jax.numpy as jnp

from repro.core import ProtocolConfig, SSLConfig, run_one_shot
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


def test_bf16_reps_half_bytes_same_auc():
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 1200)
    split = make_vfl_partition(x, y, overlap_size=128, feature_sizes=[10, 13],
                               seed=1)
    ssl = [SSLConfig(modality="tabular")] * 2
    results = {}
    for dt in (jnp.float32, jnp.bfloat16):
        ext = [make_mlp_extractor(rep_dim=16, hidden=(32,)) for _ in range(2)]
        cfg = ProtocolConfig(client_epochs=2, server_epochs=5, rep_dtype=dt)
        results[dt] = run_one_shot(jax.random.PRNGKey(1), split, ext, ssl, cfg)
    f32, bf16 = results[jnp.float32], results[jnp.bfloat16]
    assert bf16.ledger.total_bytes() * 2 == f32.ledger.total_bytes()
    assert abs(bf16.metric - f32.metric) < 0.05
    assert bf16.ledger.comm_times() == 3
