"""Integration: the dry-run driver end-to-end in a subprocess (it must own
the 512-device XLA flag, which cannot be set in this test process)."""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.mark.parametrize("arch,shape", [
    ("mamba2-370m", "long_500k"),      # fastest-compiling pair
])
def test_dryrun_subprocess_produces_record(tmp_path, arch, shape):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", arch, "--shape", shape, "--out", str(tmp_path)],
        env=env, capture_output=True, text=True, timeout=420)
    assert proc.returncode == 0, proc.stderr[-2000:]
    path = tmp_path / f"{arch}__{shape}__16x16.json"
    assert path.exists(), proc.stdout
    rec = json.loads(path.read_text())
    assert rec["mesh"] == "16x16"
    assert rec["num_params"] > 1e8
    for key in ("compute_s", "memory_s", "collective_s", "bottleneck"):
        assert key in rec["roofline"]
    ha = rec["hlo_analysis"]
    assert ha["dot_flops"] > 0
    assert ha["traffic_bytes"] > 0
