import jax
import jax.numpy as jnp
import numpy as np

from repro.data import (batch_iterator, make_image_classification,
                        make_tabular_credit, make_token_stream,
                        make_vfl_partition, split_features, split_image_halves)


def test_image_generator_shapes_and_signal():
    x, y = make_image_classification(jax.random.PRNGKey(0), 256, num_classes=4)
    assert x.shape == (256, 32, 32, 3)
    assert y.shape == (256,)
    assert int(y.max()) <= 3
    # class templates must be separable: per-class means differ
    m0 = x[y == 0].mean(0)
    m1 = x[y == 1].mean(0)
    assert float(jnp.abs(m0 - m1).mean()) > 0.05


def test_tabular_generator_cross_party_signal():
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 1000)
    assert x.shape == (1000, 23)
    assert set(np.unique(np.asarray(y))) <= {0, 1}
    # roughly balanced
    assert 0.3 < float(y.mean()) < 0.7


def test_token_stream():
    t, l = make_token_stream(jax.random.PRNGKey(0), 4, 16, 100)
    assert t.shape == (4, 16) and l.shape == (4, 16)
    assert jnp.array_equal(t[:, 1:], l[:, :-1])
    assert int(t.max()) < 100


def test_split_image_halves():
    x = jnp.zeros((8, 32, 32, 3))
    parts = split_image_halves(x, 2)
    assert parts[0].shape == (8, 32, 16, 3)
    assert parts[1].shape == (8, 32, 16, 3)


def test_split_features_sizes():
    x = jnp.arange(46).reshape(2, 23)
    a, b = split_features(x, [10, 13])
    assert a.shape == (2, 10) and b.shape == (2, 13)
    assert jnp.array_equal(jnp.concatenate([a, b], axis=1), x)


def test_vfl_partition_disjoint_and_aligned():
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 500)
    split = make_vfl_partition(x, y, overlap_size=100, feature_sizes=[10, 13],
                               test_fraction=0.2, seed=3)
    assert split.aligned[0].shape == (100, 10)
    assert split.aligned[1].shape == (100, 13)
    assert split.labels.shape == (100,)
    assert split.test_aligned[0].shape[0] == 100  # 20% of 500
    n_pool = 500 - 100 - 100
    assert split.unaligned[0].shape[0] == n_pool // 2
    assert split.unaligned[1].shape[0] == n_pool // 2


def test_batch_iterator_deterministic():
    a = jnp.arange(100)
    batches1 = [b for (b,) in batch_iterator([a], 32, 1, seed=7)]
    batches2 = [b for (b,) in batch_iterator([a], 32, 1, seed=7)]
    for x1, x2 in zip(batches1, batches2):
        assert jnp.array_equal(x1, x2)
    assert len(batches1) == 3  # drop remainder
