"""Round-trip coverage for checkpoint/ckpt.py (ISSUE 7): save/load/latest
with metadata, mixed dtypes, and missing-directory edges — the substrate
the serving artifact layer persists through."""
import os
import shutil

import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint


def _tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((4,), jnp.float32) * 0.5},
        "stack": [jnp.full((2, 2), 7.0), jnp.zeros((1,))],
    }


def test_roundtrip_values_and_metadata(tmp_path):
    d = str(tmp_path / "ck")
    tree = _tree()
    path = save_checkpoint(d, 3, tree, metadata={"note": "hello", "k": 2})
    assert os.path.exists(path) and path.endswith("ckpt_00000003.npz")

    zeros = {
        "w": jnp.zeros((3, 4), jnp.float32),
        "nested": {"b": jnp.zeros((4,), jnp.float32)},
        "stack": [jnp.zeros((2, 2)), jnp.zeros((1,))],
    }
    restored, meta = load_checkpoint(d, template=zeros)
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(tree["w"]))
    np.testing.assert_allclose(np.asarray(restored["nested"]["b"]),
                               np.asarray(tree["nested"]["b"]))
    np.testing.assert_allclose(np.asarray(restored["stack"][0]), 7.0)
    # user metadata rides along, the step slot is stamped in
    assert meta["note"] == "hello" and meta["k"] == 2 and meta["step"] == 3


def test_mixed_dtypes_restore_to_template_dtypes(tmp_path):
    d = str(tmp_path / "ck")
    tree = {
        "f32": jnp.ones((2, 3), jnp.float32),
        "bf16": jnp.ones((4,), jnp.bfloat16) * 1.5,
        "i32": jnp.arange(5, dtype=jnp.int32),
        "flag": jnp.array([True, False]),
    }
    save_checkpoint(d, 0, tree)
    template = {
        "f32": jnp.zeros((2, 3), jnp.float32),
        "bf16": jnp.zeros((4,), jnp.bfloat16),
        "i32": jnp.zeros((5,), jnp.int32),
        "flag": jnp.zeros((2,), bool),
    }
    restored, _ = load_checkpoint(d, template=template)
    assert restored["bf16"].dtype == jnp.bfloat16
    assert restored["i32"].dtype == jnp.int32
    assert restored["flag"].dtype == bool
    np.testing.assert_allclose(
        np.asarray(restored["bf16"], np.float32), 1.5)
    np.testing.assert_array_equal(np.asarray(restored["i32"]), np.arange(5))


def test_latest_step_ordering_and_selection(tmp_path):
    d = str(tmp_path / "ck")
    assert latest_step(d) is None          # directory doesn't exist yet
    for step, val in [(1, 1.0), (10, 10.0), (5, 5.0)]:
        save_checkpoint(d, step, {"x": jnp.full((2,), val)})
    assert latest_step(d) == 10
    # load picks the LATEST by default, an explicit step wins
    t = {"x": jnp.zeros((2,))}
    latest, meta = load_checkpoint(d, template=t)
    assert float(latest["x"][0]) == 10.0 and meta["step"] == 10
    five, meta5 = load_checkpoint(d, template=t, step=5)
    assert float(five["x"][0]) == 5.0 and meta5["step"] == 5


def test_deleted_directory_raises_cleanly(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 0, {"x": jnp.zeros((1,))})
    shutil.rmtree(d)
    assert latest_step(d) is None
    with pytest.raises(FileNotFoundError):
        load_checkpoint(d, template={"x": jnp.zeros((1,))})


def test_shape_mismatch_is_rejected(tmp_path):
    d = str(tmp_path / "ck")
    save_checkpoint(d, 0, {"x": jnp.zeros((3,))})
    with pytest.raises(AssertionError):
        load_checkpoint(d, template={"x": jnp.zeros((4,))})
