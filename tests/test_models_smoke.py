"""Per-architecture smoke tests (deliverable f): every assigned config's
REDUCED variant runs one forward/train step + one decode step on CPU with
correct shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs import all_configs, get_config
from repro.configs.base import InputShape
from repro.launch import specs as SP
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.model_zoo import build_model

ARCHS = sorted(all_configs())
_SMOKE = InputShape("smoke", 64, 2, "train")


def _batch(cfg, key):
    batch = SP.materialize(key, SP.train_specs(cfg, _SMOKE))
    return {k: (jnp.clip(v, 0, cfg.vocab_size - 1)
                if v.dtype == jnp.int32 else v)
            for k, v in batch.items()}


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    loss0 = model.loss_fn(params, batch)
    assert loss0.shape == ()
    assert not bool(jnp.isnan(loss0)), "NaN loss"

    tx = make_optimizer(cfg, 1e-3)
    step = jax.jit(make_train_step(model, tx))
    params2, _, loss = step(params, tx.init(params), batch)
    assert not bool(jnp.isnan(loss))
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in zip(
        jax.tree_util.tree_leaves(params), jax.tree_util.tree_leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_step_shapes(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = SP.zeros_like_spec(model.cache_shapes(2, 32))
    if cfg.family == "audio":
        from repro.models.model_zoo import _encode
        emb = 0.02 * jax.random.normal(jax.random.PRNGKey(2),
                                       (2, cfg.prefix_tokens, cfg.d_model))
        cache["enc_out"] = _encode(params, cfg, emb).astype(cache["enc_out"].dtype)
    batch = {"token": jnp.array([[1], [2]], jnp.int32),
             "pos": jnp.zeros((2, 1), jnp.int32)}
    logits, new_cache = model.decode_fn(params, cache, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert not bool(jnp.any(jnp.isnan(logits)))
    assert jax.tree_util.tree_structure(new_cache) == \
        jax.tree_util.tree_structure(cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_loss_decreases_under_training(arch):
    """A few steps on a fixed batch must reduce loss (learnable path)."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))
    tx = optim.chain(optim.clip_by_global_norm(1.0), optim.adam(3e-3))
    step = jax.jit(make_train_step(model, tx))
    opt = tx.init(params)
    first = None
    for _ in range(8):
        params, opt, loss = step(params, opt, batch)
        if first is None:
            first = float(loss)
    assert float(loss) < first
