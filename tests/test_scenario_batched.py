"""Scenario-axis folding (DESIGN.md §12): ``run_scenarios_seeds`` stacks C
grouped scenarios × S seeds × K parties onto the engine's one anonymous
batch axis and must be indistinguishable from the per-scenario loop:

* per-(scenario, seed) metrics AND parameter leaves within 1e-5 of
  ``run_seeds`` run scenario by scenario — for one-shot, few-shot, and the
  iterative scan fold;
* ledgers byte-identical per (scenario, seed) against the loop's;
* the warm-cache contract: C >= 2 adds ZERO fresh session-cache misses
  over a C = 1 run (the cache keys carry neither batch width nor data
  shapes — ``run_seeds`` IS the width-1 case of the same code);
* heterogeneous-shape grids fall back to the per-scenario path and say so
  (``scenario_fold`` 1);

plus Hypothesis property tests for the group partitioner
(``scenarios.grouping``): arbitrary catalog subsets partition into an
exact cover whose groups satisfy the engine's ``parties_are_homogeneous``
predicate across members, arch/shape mismatches fall out as singletons,
and group order is deterministic.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import engine, scenarios
from repro.core import (IterativeConfig, ProtocolConfig, SSLConfig,
                        run_few_shot, run_one_shot, run_vanilla)
from repro.core.protocol import run_scenarios_seeds, run_seeds
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor
from repro.scenarios import grouping

_FAST = ProtocolConfig(client_epochs=2, server_epochs=3)
SEEDS = (0, 1)
_SSL = [SSLConfig(modality="tabular")] * 2


def _ext():
    return [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]


def _scenario_splits(c, overlap=64):
    """One synthetic 'scenario': same shapes for every c, different data."""
    out = []
    for s in SEEDS:
        x, y = make_tabular_credit(jax.random.PRNGKey(5000 + 97 * c + s), 700)
        out.append(make_vfl_partition(x[:, :22], y, overlap_size=overlap,
                                      feature_sizes=[11, 11], seed=s))
    return out


@pytest.fixture(scope="module")
def grid_splits():
    return [_scenario_splits(0), _scenario_splits(1)]


def _run_grid(runner, grid_splits, cfg=_FAST):
    num_scenarios = len(grid_splits)
    return run_scenarios_seeds(
        runner,
        [[jax.random.PRNGKey(s) for s in SEEDS]
         for _ in range(num_scenarios)],
        grid_splits,
        [[_ext() for _ in SEEDS] for _ in range(num_scenarios)],
        [[_SSL for _ in SEEDS] for _ in range(num_scenarios)],
        cfg)


def _run_loop(runner, grid_splits, cfg=_FAST):
    return [run_seeds(runner, [jax.random.PRNGKey(s) for s in SEEDS], sp,
                      [_ext() for _ in SEEDS], [_SSL for _ in SEEDS], cfg)
            for sp in grid_splits]


def _assert_ledgers_equal(a, b):
    assert a.total_bytes() == b.total_bytes()
    assert a.comm_times() == b.comm_times()
    assert a.by_tag() == b.by_tag()


def _assert_grid_matches_loop(folded, loop):
    for scen_folded, scen_loop in zip(folded, loop):
        for res, ref in zip(scen_folded, scen_loop):
            assert abs(float(res.metric) - float(ref.metric)) < 1e-5, \
                (float(res.metric), float(ref.metric))
            assert res.diagnostics["engine_path"] == \
                ref.diagnostics["engine_path"]
            _assert_ledgers_equal(res.ledger, ref.ledger)
            for cb, cs in zip(res.clients, ref.clients):
                for lb, ls in zip(jax.tree_util.tree_leaves(cb.params),
                                  jax.tree_util.tree_leaves(cs.params)):
                    assert jnp.allclose(lb, ls, atol=1e-5), \
                        float(jnp.max(jnp.abs(lb - ls)))


def test_scenario_fold_matches_per_scenario_loop_one_shot(grid_splits):
    """The tentpole parity: one folded C=2 × S=2 one-shot sweep == the
    per-scenario ``run_seeds`` loop at 1e-5 on metric and every client
    parameter leaf, with byte-identical per-(scenario, seed) ledgers."""
    folded = _run_grid(run_one_shot, grid_splits)
    loop = _run_loop(run_one_shot, grid_splits)
    _assert_grid_matches_loop(folded, loop)
    flat = [r for scen in folded for r in scen]
    assert len({id(r.ledger) for r in flat}) == len(flat)   # per-entry copies
    for r in flat:
        assert r.diagnostics["seed_fold"] == len(SEEDS)
        assert r.diagnostics["scenario_fold"] == len(grid_splits)
    # communication is a shape function: byte-identity holds across the
    # whole flat batch, not just within a scenario
    for r in flat[1:]:
        _assert_ledgers_equal(r.ledger, flat[0].ledger)


def test_scenario_fold_matches_per_scenario_loop_few_shot(grid_splits):
    """Same parity through the whole few-shot pipeline (aux fits, SDPA
    gating, masked phase ⑤', final re-fit) — including the Eq. 9 gate's
    per-party take rates, which must not feel their fold neighbors."""
    folded = _run_grid(run_few_shot, grid_splits)
    loop = _run_loop(run_few_shot, grid_splits)
    _assert_grid_matches_loop(folded, loop)
    for scen_folded, scen_loop in zip(folded, loop):
        for res, ref in zip(scen_folded, scen_loop):
            assert res.diagnostics["fewshot_take_rate"] == \
                ref.diagnostics["fewshot_take_rate"]


def test_scenario_fold_matches_per_scenario_loop_iterative(grid_splits):
    """The §11 scan fold rides the same anonymous axis: C·S stacked
    whole-session carries == the per-scenario loop, on whichever engine
    path the CI matrix leg steers (loop parity already asserts folded
    path == loop path per entry)."""
    icfg = IterativeConfig(iterations=10)
    folded = _run_grid(run_vanilla, grid_splits, icfg)
    loop = _run_loop(run_vanilla, grid_splits, icfg)
    _assert_grid_matches_loop(folded, loop)
    for scen in folded:
        for r in scen:
            assert r.diagnostics["engine_path"] in ("scan", "python")
            assert r.diagnostics["scenario_fold"] == len(grid_splits)


def test_scenario_fold_adds_zero_fresh_session_misses(grid_splits):
    """The warm-cache contract behind the grouped frontier: after a C = 1
    run, folding C >= 2 scenarios must add ZERO fresh session-cache misses
    in ANY domain — same model identity, same hparams, and the keys carry
    neither batch width nor data shapes. (The cache is deliberately NOT
    cleared between the two runs: the C >= 2 sweep must re-serve the C = 1
    programs.)"""
    engine.clear_session_cache()
    run_seeds(run_few_shot, [jax.random.PRNGKey(s) for s in SEEDS],
              grid_splits[0], [_ext() for _ in SEEDS],
              [_SSL for _ in SEEDS], _FAST)
    warm = {d: st["misses"]
            for d, st in engine.session_cache_stats_by_domain().items()}
    _run_grid(run_few_shot, grid_splits)
    after = {d: st["misses"]
             for d, st in engine.session_cache_stats_by_domain().items()}
    assert after == warm, (warm, after)


def test_heterogeneous_grid_falls_back_per_scenario(grid_splits):
    """Scenarios whose splits don't share one shape cannot stack: the grid
    runs scenario by scenario (each still seed-folded) and the results say
    so via scenario_fold — the signal the frontier gate asserts on."""
    grid = [grid_splits[0], _scenario_splits(1, overlap=96)]
    folded = _run_grid(run_one_shot, grid)
    loop = _run_loop(run_one_shot, grid)
    _assert_grid_matches_loop(folded, loop)
    for scen in folded:
        for r in scen:
            assert r.diagnostics["scenario_fold"] == 1
            assert r.diagnostics["seed_fold"] == len(SEEDS)


def test_run_seeds_is_the_width_one_case(grid_splits):
    """C = 1 through ``run_seeds`` reports scenario_fold 1 — the width-1
    invariant the C >= 2 fold generalizes (same impls, same cache keys)."""
    results = run_seeds(run_one_shot, [jax.random.PRNGKey(s) for s in SEEDS],
                        grid_splits[0], [_ext() for _ in SEEDS],
                        [_SSL for _ in SEEDS], _FAST)
    for r in results:
        assert r.diagnostics["scenario_fold"] == 1
        assert r.diagnostics["seed_fold"] == len(SEEDS)


def test_run_scenarios_seeds_rejects_state_kwargs_and_ragged_grids(
        grid_splits):
    keys = [[jax.random.PRNGKey(s) for s in SEEDS] for _ in range(2)]
    ext = [[_ext() for _ in SEEDS] for _ in range(2)]
    ssl = [[_SSL for _ in SEEDS] for _ in range(2)]
    with pytest.raises(ValueError, match="state kwargs"):
        run_scenarios_seeds(run_one_shot, keys, grid_splits, ext, ssl,
                            _FAST, clients=None)
    ragged = [grid_splits[0], grid_splits[1][:1]]
    with pytest.raises(ValueError, match="rectangular"):
        run_scenarios_seeds(run_one_shot, keys, ragged, ext, ssl, _FAST)


# ------------------------------------------------ partitioner properties
import random  # noqa: E402

_NAMES = scenarios.names()
_CATALOG: dict = {}


def _entry(name):
    """Built catalog entry (spec, bundle), cached across examples —
    building draws the synthetic dataset, grouping does not."""
    if name not in _CATALOG:
        bundle = scenarios.build(name, seed=0, smoke=True)
        _CATALOG[name] = (bundle.spec, bundle)
    return _CATALOG[name]


def _check_exact_cover_of_homogeneous_groups(subset):
    """Any catalog subset partitions into an exact cover; within every
    group the engine's ``parties_are_homogeneous`` predicate holds across
    members party position by party position (plus full shape equality) —
    the stackability ground truth behind the fold signature."""
    entries = [_entry(n) for n in subset]
    groups = scenarios.group_scenarios(entries)
    flat = sorted(i for g in groups for i in g.indices)
    assert flat == list(range(len(entries)))
    for g in groups:
        assert g.names == [entries[i][0].name for i in g.indices]
        head = entries[g.indices[0]][1]
        for i in g.indices[1:]:
            assert grouping.bundles_fold_compatible(entries[i][1], head)
            assert grouping.split_signature(entries[i][1].split) == \
                grouping.split_signature(head.split)


def _check_deterministic_and_order_preserving(subset):
    """Same input ⇒ same groups, groups in first-occurrence order, members
    in input order — the frontier's row order must be reproducible."""
    entries = [_entry(n) for n in subset]
    first = scenarios.group_scenarios(entries)
    second = scenarios.group_scenarios(entries)
    assert [g.indices for g in first] == [g.indices for g in second]
    assert [g.names for g in first] == [g.names for g in second]
    assert [g.indices[0] for g in first] == \
        sorted(g.indices[0] for g in first)
    for g in first:
        assert g.indices == sorted(g.indices)


def _fixed_subsets():
    """Deterministic fallback corpus for images without Hypothesis: the
    full catalog, every singleton, and seeded random subsets/orderings."""
    rng = random.Random(0)
    subsets = [list(_NAMES)] + [[n] for n in _NAMES]
    for _ in range(15):
        k = rng.randint(1, len(_NAMES))
        subsets.append(rng.sample(_NAMES, k))
    return subsets


def test_partitioner_properties_on_fixed_subsets():
    """The partitioner invariants on a deterministic corpus — always runs
    in tier-1, with or without Hypothesis."""
    for subset in _fixed_subsets():
        _check_exact_cover_of_homogeneous_groups(subset)
        _check_deterministic_and_order_preserving(subset)


def test_partition_is_exact_cover_of_homogeneous_groups_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hypothesis.settings(max_examples=25, deadline=None)
    @hypothesis.given(st.lists(st.sampled_from(_NAMES), unique=True,
                               min_size=1))
    def check(subset):
        _check_exact_cover_of_homogeneous_groups(subset)

    check()


def test_partition_deterministic_and_order_preserving_hypothesis():
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import strategies as st

    @hypothesis.settings(max_examples=25, deadline=None)
    @hypothesis.given(st.lists(st.sampled_from(_NAMES), unique=True,
                               min_size=1))
    def check(subset):
        _check_deterministic_and_order_preserving(subset)

    check()


def test_partition_none_and_distinct_signatures_are_singletons():
    """The pure bucketing law: ``None`` (unhashable) signatures never
    group, equal signatures always do, order is first-occurrence."""
    assert grouping.partition(["a", None, "a", "b", None]) == \
        [[0, 2], [1], [3], [4]]
    assert grouping.partition([]) == []


def test_arch_mismatch_falls_out_as_singleton():
    """Equal shapes with a DIFFERENT architecture must not group: the
    signature carries the apply-fn identity (``model_key``), exactly like
    the engine predicate."""
    spec, bundle = _entry("credit/overlap-32")
    other = dataclasses.replace(
        bundle, extractors=[make_mlp_extractor(rep_dim=16, hidden=(32,))
                            for _ in range(2)])
    groups = scenarios.group_scenarios([(spec, bundle), (spec, other)])
    assert [g.indices for g in groups] == [[0], [1]]
    assert not grouping.bundles_fold_compatible(bundle, other)


def test_known_catalog_groups():
    """Pin the catalog's smoke-size group structure the frontier relies
    on: the credit sweep family folds into one stack; hard/* (different
    N_o ⇒ different schedule shapes) and the party-count/feature-skew
    variants stay apart."""
    entries = [_entry(n) for n in _NAMES]
    groups = scenarios.group_scenarios(entries)
    group_of = {n: gi for gi, g in enumerate(groups) for n in g.names}
    family = ["credit/overlap-32", "credit/overlap-64", "credit/overlap-128",
              "credit/overlap-256", "credit/label-noise"]
    assert len({group_of[n] for n in family}) == 1
    assert group_of["hard/overlap-32"] != group_of["hard/overlap-64"]
    # the equal-shape variants exist precisely to close that gap: a fixed
    # 64-row aligned capacity + validity mask gives both members ONE shape
    # signature, so they stack — while staying apart from the unmasked
    # hard/overlap-64 (same shapes, but the mask changes the loss)
    assert group_of["hard/overlap-32-eq"] == group_of["hard/overlap-64-eq"]
    assert group_of["hard/overlap-64-eq"] != group_of["hard/overlap-64"]
    for loner in ("credit/feature-skew", "credit/parties-4",
                  "credit/parties-8", "image/halves", "image/patch-4"):
        assert sum(1 for n in _NAMES if group_of[n] == group_of[loner]) == 1
