"""Coverage for the beyond-paper extensions: few-shot+finetune row, the
grad-DP noise hook, the fused RMSNorm kernel, and the zoo-backbone VFL
integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ProtocolConfig, SSLConfig, run_few_shot_finetune,
                        run_one_shot)
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


@pytest.fixture(scope="module")
def split():
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 1200)
    return make_vfl_partition(x, y, overlap_size=96, feature_sizes=[10, 13],
                              seed=1)


def _ext():
    return [make_mlp_extractor(rep_dim=16, hidden=(32,)) for _ in range(2)]


_SSL = [SSLConfig(modality="tabular")] * 2


def test_few_shot_finetune_row(split):
    """Tab. 1 last row: finetuning adds iterative comm on top of few-shot's
    5 rounds, and the combined ledger shows it."""
    res = run_few_shot_finetune(jax.random.PRNGKey(1), split, _ext(), _SSL,
                                ProtocolConfig(client_epochs=2, server_epochs=5),
                                finetune_iterations=30)
    assert res.metric > 0.6
    assert "fewshot_metric" in res.diagnostics
    # 5 few-shot rounds + 2×30 finetune rounds
    assert res.ledger.comm_times() == 5 + 60


def test_grad_dp_noise_degrades_gracefully(split):
    """Gaussian noise on the partial gradients (label-DP-style defense):
    small σ keeps clustering purity high; huge σ destroys it — the
    privacy/utility dial the paper's §6 points at."""
    purities = {}
    for sigma in (0.0, 0.3, 50.0):
        cfg = ProtocolConfig(client_epochs=1, server_epochs=2,
                             grad_dp_sigma=sigma)
        res = run_one_shot(jax.random.PRNGKey(1), split, _ext(), _SSL, cfg)
        purities[sigma] = float(np.mean(res.diagnostics["kmeans_purity"]))
    assert purities[0.0] > 0.9
    assert purities[0.3] > 0.75              # mild noise: clustering robust
    assert purities[50.0] < purities[0.0]    # overwhelming noise: signal gone


def test_rmsnorm_kernel_sweep():
    from repro.kernels.rmsnorm import ops, ref

    key = jax.random.PRNGKey(0)
    for shape, dt, tol in [((4, 7, 96), jnp.float32, 1e-5),
                           ((33, 1024), jnp.bfloat16, 3e-2),
                           ((2, 3, 5, 130), jnp.float32, 1e-5),
                           ((8, 8), jnp.float32, 1e-5)]:
        k1, k2, key = jax.random.split(key, 3)
        x = jax.random.normal(k1, shape).astype(dt)
        s = (1.0 + 0.1 * jax.random.normal(k2, shape[-1:])).astype(dt)
        got = ops.rms_norm(x, s)
        want = ref.rms_norm(x, s)
        err = float(jnp.max(jnp.abs(got.astype(jnp.float32)
                                    - want.astype(jnp.float32))))
        assert err < tol, (shape, dt, err)
        assert got.dtype == x.dtype


def test_zoo_backbone_extractor_in_protocol():
    """DESIGN.md §4 integration: a reduced assigned-arch backbone as f_k."""
    from repro.configs import get_config
    from repro.data.synthetic import make_sequence_classification
    from repro.data.vertical import VerticalSplit
    from repro.models.zoo_extractor import make_zoo_extractor

    x, y = make_sequence_classification(jax.random.PRNGKey(0), 400,
                                        seq_len=16, vocab_size=32,
                                        num_classes=3)
    rng = np.random.RandomState(0)
    perm = rng.permutation(400)
    test, over, rest = perm[:80], perm[80:144], perm[144:]
    pool = np.array_split(rest, 2)
    split = VerticalSplit(
        aligned=[x[over, :8], x[over, 8:]], labels=y[over],
        unaligned=[x[pool[0], :8], x[pool[1], 8:]],
        test_aligned=[x[test, :8], x[test, 8:]], test_labels=y[test],
        num_classes=3)

    cfg = dataclasses.replace(get_config("phi4-mini-3.8b").reduced(),
                              vocab_size=32, num_layers=2)
    ext = [make_zoo_extractor(cfg, rep_dim=16) for _ in range(2)]
    ssl = [SSLConfig(modality="token")] * 2
    res = run_one_shot(jax.random.PRNGKey(1), split, ext, ssl,
                       ProtocolConfig(client_epochs=3, server_epochs=10,
                                      client_lr=0.02))
    assert res.metric > 0.4          # chance 0.33
    assert res.ledger.comm_times() == 3
