"""Seed-batched execution (DESIGN.md §10-11): ``run_seeds`` folds S seeds
of one scenario point into the engine's stacked programs and must be
indistinguishable from a Python loop of single-seed runs:

* per-seed metrics within 1e-5 of the loop's (params too, for one-shot
  and the iterative baselines);
* ledgers byte-identical — across seeds AND against the loop;
* seeds >= 2 add ZERO fresh compiled-session builds over a 1-seed run
  (the cache keys carry no batch width; ``jax.jit`` re-specializes the
  one cached session per stacked shape);
* the seed-folded k-means is bit-identical to the per-call path;
* the ITERATIVE fold (§11): ``run_vanilla``/``run_fedcvt``/``run_fedbcd``
  stack their whole-session scan carries on a leading seed axis, and the
  chained ``run_few_shot_finetune`` threads the folded few-shot output
  carry straight into the folded finetune session.

Plus the single-seed blind-spot regressions PR 4 fixed:

* ``build_schedule``'s epoch-0 labeled/unlabeled RNG-stream collision;
* the ``n_unlabeled == 0`` (full-overlap party) NaN;
* ``parties_are_homogeneous`` — the spec-level engine predicate (apply-fn
  identity, not the shape heuristic);
* few-shot phase ⑤' reusing the step-③ cluster pseudo-labels Ŷ_o^k.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import (IterativeConfig, ProtocolConfig, SSLConfig,
                        run_fedbcd, run_fedcvt, run_few_shot,
                        run_few_shot_finetune, run_one_shot, run_vanilla)
from repro.core.protocol import fewshot_phase5_labels, run_seeds
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor

_FAST = ProtocolConfig(client_epochs=2, server_epochs=3)
SEEDS = (0, 1)


def _splits():
    out = []
    for s in SEEDS:
        x, y = make_tabular_credit(jax.random.PRNGKey(1000 + s), 700)
        out.append(make_vfl_partition(x[:, :22], y, overlap_size=64,
                                      feature_sizes=[11, 11], seed=s))
    return out


def _ext():
    return [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]


_SSL = [SSLConfig(modality="tabular")] * 2


def _run_seeds(runner, splits, cfg=_FAST):
    return run_seeds(runner, [jax.random.PRNGKey(s) for s in SEEDS], splits,
                     [_ext() for _ in SEEDS], [_SSL for _ in SEEDS], cfg)


def _assert_ledgers_equal(a, b):
    assert a.total_bytes() == b.total_bytes()
    assert a.comm_times() == b.comm_times()
    assert a.by_tag() == b.by_tag()


@pytest.fixture(scope="module")
def splits():
    return _splits()


def test_run_seeds_matches_single_seed_loop_one_shot(splits):
    """The tentpole parity: the S·K-folded one-shot run per seed == the
    single-seed runner, at 1e-5 on the metric AND every client parameter
    leaf, with byte-identical ledgers."""
    batched = _run_seeds(run_one_shot, splits)
    assert batched[0].ledger is not batched[1].ledger
    for s, split in zip(SEEDS, splits):
        solo = run_one_shot(jax.random.PRNGKey(s), split, _ext(), _SSL, _FAST)
        res = batched[SEEDS.index(s)]
        assert abs(float(res.metric) - float(solo.metric)) < 1e-5, \
            (s, float(res.metric), float(solo.metric))
        _assert_ledgers_equal(res.ledger, solo.ledger)
        for cb, cs in zip(res.clients, solo.clients):
            for lb, ls in zip(jax.tree_util.tree_leaves(cb.params),
                              jax.tree_util.tree_leaves(cs.params)):
                assert jnp.allclose(lb, ls, atol=1e-5), \
                    float(jnp.max(jnp.abs(lb - ls)))
    # byte-identity ACROSS seeds too (communication is a shape function)
    _assert_ledgers_equal(batched[0].ledger, batched[1].ledger)


def test_run_seeds_matches_single_seed_loop_few_shot(splits):
    """Same parity through the whole few-shot pipeline (aux fits, SDPA
    gating, masked phase ⑤', final re-fit)."""
    batched = _run_seeds(run_few_shot, splits)
    for s, split in zip(SEEDS, splits):
        solo = run_few_shot(jax.random.PRNGKey(s), split, _ext(), _SSL, _FAST)
        res = batched[SEEDS.index(s)]
        assert abs(float(res.metric) - float(solo.metric)) < 1e-5, \
            (s, float(res.metric), float(solo.metric))
        _assert_ledgers_equal(res.ledger, solo.ledger)
        assert res.diagnostics["fewshot_take_rate"] == \
            solo.diagnostics["fewshot_take_rate"]
    _assert_ledgers_equal(batched[0].ledger, batched[1].ledger)


def test_seed_batch_adds_zero_fresh_compiles(splits):
    """Seeds >= 2 must add ZERO fresh compiled-session builds over a
    single-seed run: the session cache keys on semantic step identity,
    never on the stacked batch width."""
    engine.clear_session_cache()
    run_seeds(run_few_shot, [jax.random.PRNGKey(0)], splits[:1], [_ext()],
              [_SSL], _FAST)
    one_seed = {d: st["misses"]
                for d, st in engine.session_cache_stats_by_domain().items()}
    engine.clear_session_cache()
    _run_seeds(run_few_shot, splits)
    two_seeds = {d: st["misses"]
                 for d, st in engine.session_cache_stats_by_domain().items()}
    assert two_seeds == one_seed, (one_seed, two_seeds)


@pytest.mark.parametrize("runner,icfg", [
    (run_vanilla, IterativeConfig(iterations=20)),
    (run_fedcvt, IterativeConfig(iterations=10)),
    (run_fedbcd, IterativeConfig(iterations=20)),
], ids=["vanilla", "fedcvt", "fedbcd"])
def test_run_seeds_matches_single_seed_loop_iterative(runner, icfg, splits):
    """The §11 parity: every iterative baseline's seed fold (stacked
    whole-session scan carries, one vmap-of-scan program) per seed == the
    single-seed runner at 1e-5 on the metric AND every client parameter
    leaf, with byte-identical ledgers across seeds and vs the loop."""
    batched = _run_seeds(runner, splits, icfg)
    assert batched[0].ledger is not batched[1].ledger
    _assert_ledgers_equal(batched[0].ledger, batched[1].ledger)
    for s, split in zip(SEEDS, splits):
        solo = runner(jax.random.PRNGKey(s), split, _ext(), _SSL, icfg)
        res = batched[SEEDS.index(s)]
        assert abs(float(res.metric) - float(solo.metric)) < 1e-5, \
            (s, float(res.metric), float(solo.metric))
        _assert_ledgers_equal(res.ledger, solo.ledger)
        assert res.diagnostics["engine_path"] == \
            solo.diagnostics["engine_path"]
        for cb, cs in zip(res.clients, solo.clients):
            for lb, ls in zip(jax.tree_util.tree_leaves(cb.params),
                              jax.tree_util.tree_leaves(cs.params)):
                assert jnp.allclose(lb, ls, atol=1e-5), \
                    float(jnp.max(jnp.abs(lb - ls)))


def test_seed_batched_iterative_adds_zero_fresh_compiles(splits):
    """Seeds >= 2 of an iterative baseline must add ZERO fresh compiled-
    session builds over a single-seed run: the width-1 session IS the
    folded session (one cache key, no batch width in it)."""
    icfg = IterativeConfig(iterations=10)
    engine.clear_session_cache()
    run_seeds(run_vanilla, [jax.random.PRNGKey(0)], splits[:1], [_ext()],
              [_SSL], icfg)
    one_seed = {d: st["misses"]
                for d, st in engine.session_cache_stats_by_domain().items()}
    # the stronger §11 guarantee: a LATER multi-seed run re-serves the
    # single-seed program — don't even clear the cache
    _run_seeds(run_vanilla, splits, icfg)
    two_seeds = {d: st["misses"]
                 for d, st in engine.session_cache_stats_by_domain().items()}
    assert two_seeds == one_seed, (one_seed, two_seeds)


def test_run_seeds_few_shot_finetune_chains_the_folds(splits):
    """The chained fold: seed-batched few-shot hands its per-seed output
    state to the seed-batched vanilla finetune inside one ``run_seeds``
    call — per seed == the single-seed ``run_few_shot_finetune`` at 1e-5,
    with the combined (few-shot + finetune) ledger byte-identical."""
    batched = run_seeds(run_few_shot_finetune,
                        [jax.random.PRNGKey(s) for s in SEEDS], splits,
                        [_ext() for _ in SEEDS], [_SSL for _ in SEEDS],
                        _FAST, finetune_iterations=20)
    _assert_ledgers_equal(batched[0].ledger, batched[1].ledger)
    for s, split in zip(SEEDS, splits):
        solo = run_few_shot_finetune(jax.random.PRNGKey(s), split, _ext(),
                                     _SSL, _FAST, finetune_iterations=20)
        res = batched[SEEDS.index(s)]
        assert abs(float(res.metric) - float(solo.metric)) < 1e-5, \
            (s, float(res.metric), float(solo.metric))
        assert abs(res.diagnostics["fewshot_metric"]
                   - solo.diagnostics["fewshot_metric"]) < 1e-5
        _assert_ledgers_equal(res.ledger, solo.ledger)
        # the combined ledger spans both stages: 5 few-shot comm times
        # plus 2 per finetune iteration
        assert res.ledger.comm_times() == 5 + 2 * 20


def test_run_seeds_unregistered_runner_falls_back_to_loop(splits):
    """Runners outside the batched_impl registry still work: run_seeds
    loops per seed over the runner's cached sessions, asserts ledger
    byte-identity post hoc, and each seed matches a direct call."""
    icfg = IterativeConfig(iterations=10)

    def wrapped_vanilla(key, split, extractors, ssl_cfgs, cfg, **kw):
        return run_vanilla(key, split, extractors, ssl_cfgs, cfg, **kw)

    results = run_seeds(wrapped_vanilla, [jax.random.PRNGKey(s) for s in SEEDS],
                        splits, [_ext() for _ in SEEDS],
                        [_SSL for _ in SEEDS], icfg)
    _assert_ledgers_equal(results[0].ledger, results[1].ledger)
    solo = run_vanilla(jax.random.PRNGKey(SEEDS[0]), splits[0], _ext(), _SSL,
                       icfg)
    assert float(results[0].metric) == pytest.approx(float(solo.metric),
                                                     abs=1e-6)


def test_run_seeds_heterogeneous_splits_fall_back_to_loop():
    """Seed sets whose splits don't share one shape take the same loop —
    even for a registered runner — and the ledger identity still holds
    when the byte-determining shapes (bs, rep_dim, iterations) agree."""
    splits = []
    for s, overlap in zip(SEEDS, (64, 96)):   # n differs; bs=32 both
        x, y = make_tabular_credit(jax.random.PRNGKey(2000 + s), 700)
        splits.append(make_vfl_partition(x[:, :22], y, overlap_size=overlap,
                                         feature_sizes=[11, 11], seed=s))
    icfg = IterativeConfig(iterations=10)
    results = run_seeds(run_vanilla, [jax.random.PRNGKey(s) for s in SEEDS],
                        splits, [_ext() for _ in SEEDS],
                        [_SSL for _ in SEEDS], icfg)
    _assert_ledgers_equal(results[0].ledger, results[1].ledger)
    for res in results:
        assert res.diagnostics["seed_fold"] == 1   # looped, not folded


def test_run_seeds_rejects_per_seed_state_kwargs(splits):
    """One clients/server/ledger object cannot serve S seeds — run_seeds
    must refuse instead of crashing in the batched path or silently
    accumulating a shared ledger in the loop path."""
    with pytest.raises(ValueError, match="state kwargs"):
        run_seeds(run_one_shot, [jax.random.PRNGKey(0)], splits[:1],
                  [_ext()], [_SSL], _FAST, clients=None)


def test_pseudo_labels_seeds_bit_identical_to_per_call():
    """The seed-folded k-means (one vmapped program over the S·K gradient
    stack) must assign exactly the labels of the per-call path."""
    grads = jax.random.normal(jax.random.PRNGKey(0), (6, 32, 16))
    keys = list(jax.random.split(jax.random.PRNGKey(7), 6))
    folded = engine.pseudo_labels_seeds(keys, list(grads), num_classes=2,
                                        kmeans_iters=25)
    for k, g, f in zip(keys, grads, folded):
        eager = engine.pseudo_labels(k, g, 2, 25)
        assert bool(jnp.all(f == eager))


# --------------------------------------- kernel-route folds (DESIGN.md §15)
def test_pseudo_labels_seeds_use_kernels_keeps_the_fold():
    """The retired fallback: seeds >= 2 under ``use_kernels=True`` must run
    the ONE batched Pallas grid — ``info["fold"]`` records the full stacked
    width, no fallback reason — and match the per-call kernel path
    bit-exactly."""
    grads = jax.random.normal(jax.random.PRNGKey(0), (6, 32, 16))
    keys = list(jax.random.split(jax.random.PRNGKey(7), 6))
    info = {}
    folded = engine.pseudo_labels_seeds(keys, list(grads), num_classes=2,
                                        kmeans_iters=25, use_kernels=True,
                                        info=info)
    assert info["fold"] == 6
    assert "fallback" not in info
    for k, g, f in zip(keys, grads, folded):
        eager = engine.pseudo_labels(k, g, 2, 25, use_kernels=True)
        assert bool(jnp.all(f == eager))
        # and the kernel route assigns exactly the jnp route's labels
        assert bool(jnp.all(f == engine.pseudo_labels(k, g, 2, 25)))


def test_pseudo_labels_seeds_ragged_fallback_is_recorded():
    """Only ragged gradient stacks may take the per-entry loop now — and
    the reason lands in ``info`` (→ ``kernel_fallback`` on result rows)."""
    keys = list(jax.random.split(jax.random.PRNGKey(3), 2))
    grads = [jax.random.normal(jax.random.PRNGKey(0), (32, 16)),
             jax.random.normal(jax.random.PRNGKey(1), (40, 16))]
    info = {}
    folded = engine.pseudo_labels_seeds(keys, grads, num_classes=2,
                                        use_kernels=True, info=info)
    assert info["fold"] == 1
    assert "ragged" in info["fallback"]
    for k, g, f in zip(keys, grads, folded):
        assert bool(jnp.all(f == engine.pseudo_labels(k, g, 2,
                                                      use_kernels=True)))


def test_run_seeds_use_kernels_one_shot_parity_and_fold(splits):
    """One-shot under the kernel route: per-seed metric == the jnp route's
    (the kernel assignment is bit-equal to the oracle), and every result
    records kernel_fold == S·K with no fallback."""
    cfg = dataclasses.replace(_FAST, use_kernels=True)
    kernel = _run_seeds(run_one_shot, splits, cfg)
    plain = _run_seeds(run_one_shot, splits)
    for rk, rj in zip(kernel, plain):
        assert abs(float(rk.metric) - float(rj.metric)) < 1e-5
        assert rk.diagnostics["kernel_fold"] == len(SEEDS) * 2   # S=2 × K=2
        assert "kernel_fallback" not in rk.diagnostics


def test_run_seeds_use_kernels_few_shot_matches_solo_kernel_route(splits):
    """Few-shot under ``use_kernels=True``: the seed fold == the solo run
    on the SAME route at 1e-5 (take rates exactly equal), with the fold
    diagnostics pinning the stacked widths — kernel_fold S·K on the folded
    rows vs 1·K solo, sdpa_fold S vs 1."""
    cfg = dataclasses.replace(_FAST, use_kernels=True)
    batched = _run_seeds(run_few_shot, splits, cfg)
    for s, split in zip(SEEDS, splits):
        solo = run_few_shot(jax.random.PRNGKey(s), split, _ext(), _SSL, cfg)
        res = batched[SEEDS.index(s)]
        assert abs(float(res.metric) - float(solo.metric)) < 1e-5, \
            (s, float(res.metric), float(solo.metric))
        assert res.diagnostics["fewshot_take_rate"] == \
            solo.diagnostics["fewshot_take_rate"]
        _assert_ledgers_equal(res.ledger, solo.ledger)
        assert res.diagnostics["kernel_fold"] == len(SEEDS) * 2
        assert solo.diagnostics["kernel_fold"] == 2               # 1 seed × K
        assert res.diagnostics["sdpa_fold"] == len(SEEDS)
        assert solo.diagnostics["sdpa_fold"] == 1
        assert "kernel_fallback" not in res.diagnostics


def test_use_kernels_seed_batch_adds_zero_fresh_compiles(splits):
    """The cache discipline holds on the kernel route too: seeds >= 2 add
    ZERO fresh session builds over a 1-seed kernel-route run (the kmeans/
    sdpa/fewshot_gate keys carry the route, never the width)."""
    cfg = dataclasses.replace(_FAST, use_kernels=True)
    engine.clear_session_cache()
    run_seeds(run_few_shot, [jax.random.PRNGKey(0)], splits[:1], [_ext()],
              [_SSL], cfg)
    one_seed = {d: st["misses"]
                for d, st in engine.session_cache_stats_by_domain().items()}
    engine.clear_session_cache()
    _run_seeds(run_few_shot, splits, cfg)
    two_seeds = {d: st["misses"]
                 for d, st in engine.session_cache_stats_by_domain().items()}
    assert two_seeds == one_seed, (one_seed, two_seeds)
    for domain in ("kmeans", "sdpa", "fewshot_gate"):
        assert two_seeds.get(domain, 0) >= 1, (domain, two_seeds)


# ------------------------------------------------- satellite regressions
def test_build_schedule_epoch0_streams_decorrelated():
    """Epoch 0's labeled shuffle and unlabeled draws historically seeded
    RandomState(seed0) BOTH (7919·e ≡ 0 at e = 0): the first epoch's two
    streams were generated from one generator state. Pin the fix: the
    unlabeled stream is offset (``_UNLABELED_STREAM``) and no longer
    reproduces the buggy draw."""
    from repro.engine.local_ssl import _UNLABELED_STREAM

    key = jax.random.PRNGKey(3)
    hp = engine.SSLHParams(epochs=1, batch_size=32, unlabeled_ratio=2)
    sched = engine.build_schedule(key, n_labeled=64, n_unlabeled=500, hp=hp)
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    fixed = np.random.RandomState(seed0 + _UNLABELED_STREAM)
    buggy = np.random.RandomState(seed0)           # the old e=0 stream ==
    idx_u = np.asarray(sched.idx_unlabeled)        # the labeled-shuffle seed
    assert np.array_equal(idx_u[0], fixed.randint(0, 500, size=64))
    assert not np.array_equal(idx_u[0], buggy.randint(0, 500, size=64))
    # the labeled epoch stream is untouched
    from repro.data.loader import epoch_batches

    expect_l = list(epoch_batches(64, 32, seed0))
    assert np.array_equal(np.asarray(sched.idx_labeled), np.stack(expect_l))


def test_empty_unlabeled_pool_trains_without_nan():
    """n_unlabeled == 0 (a full-overlap party): zero-width unlabeled
    batches, l_u exactly 0, finite loss — no empty-mean NaN, no randint
    crash."""
    hp = engine.SSLHParams(epochs=2, batch_size=16)
    sched = engine.build_schedule(jax.random.PRNGKey(0), n_labeled=32,
                                  n_unlabeled=0, hp=hp)
    assert sched.idx_unlabeled.shape == (4, 0)

    x, y = make_tabular_credit(jax.random.PRNGKey(0), 700)
    split = make_vfl_partition(x[:, :22], y, overlap_size=560,
                               feature_sizes=[11, 11], seed=1)
    assert all(u.shape[0] == 0 for u in split.unaligned)
    res = run_one_shot(jax.random.PRNGKey(1), split, _ext(), _SSL, _FAST)
    assert np.isfinite(float(res.metric))
    for m in res.diagnostics["ssl_metrics"]:
        assert np.isfinite(m["loss"]), m
        assert m["l_u"] == 0.0
        assert m["pseudo_mask_rate"] == 0.0


def test_full_overlap_scenario_registered_and_runs():
    """The registry's full-overlap edge scenario builds with empty pools
    and trains end to end (the smoke() shrink must not reintroduce
    unaligned rows)."""
    from repro import scenarios

    bundle = scenarios.build("edge/full-overlap", seed=0, smoke=True)
    assert all(u.shape[0] == 0 for u in bundle.split.unaligned)
    res = run_one_shot(jax.random.PRNGKey(0), bundle.split,
                       bundle.extractors, bundle.ssl_cfgs, _FAST)
    assert np.isfinite(float(res.metric))
    assert res.ledger.comm_times() == 3
    # few-shot too: the gate sees zero unaligned rows (rate 0) and the
    # masked phase-⑤' sessions run on the all-overlap labeled sets
    few = run_few_shot(jax.random.PRNGKey(0), bundle.split,
                       bundle.extractors, bundle.ssl_cfgs, _FAST)
    assert np.isfinite(float(few.metric))
    assert few.ledger.comm_times() == 5
    assert few.diagnostics["fewshot_gate_rate"] == [0.0, 0.0]


def test_fedcvt_empty_private_pool_trains_without_crash():
    """FedCVT on a full-overlap scenario: ``build_unaligned_schedule``
    historically crashed on an empty pool (``randint(0, 0)``); it must
    yield zero-width unaligned batches instead, whose masked pseudo-label
    term contributes exactly 0 (the full-catalog grouped smoke runs
    fedcvt on edge/full-overlap, so this is now a bench-critical path)."""
    from repro import scenarios
    from repro.engine import iterative

    scheds = iterative.build_unaligned_schedule(
        seed=0, pool_sizes=(0, 500), batch_size=32, iterations=5)
    assert scheds[0].shape == (5, 0)
    assert scheds[1].shape == (5, 32)

    bundle = scenarios.build("edge/full-overlap", seed=0, smoke=True)
    res = run_fedcvt(jax.random.PRNGKey(0), bundle.split, bundle.extractors,
                     bundle.ssl_cfgs, IterativeConfig(iterations=5))
    assert np.isfinite(float(res.metric))


def test_parties_are_homogeneous_is_not_a_shape_heuristic():
    """The spec-level predicate must track the engine's real precondition:
    equal feature dims with DIFFERENT forward functions are heterogeneous
    (the Python fallback is legitimate there), unequal dims are too, and
    unequal SSL configs are too."""
    from repro.models import Model

    ext = _ext()
    shapes = [(64, 11), (64, 11)]
    assert engine.parties_are_homogeneous(ext, _SSL, shapes)

    def odd_apply(params, x, train=False):
        del train
        return jnp.tanh(x @ params["w0"] + params["b0"]) @ params["w1"] \
            + params["b1"]

    odd = Model(init=ext[1].init, apply=odd_apply, rep_dim=8)
    assert not engine.parties_are_homogeneous([ext[0], odd], _SSL, shapes)
    assert not engine.parties_are_homogeneous(ext, _SSL, [(64, 11), (64, 9)])
    mixed = [_SSL[0], dataclasses.replace(_SSL[1], mask_ratio=0.5)]
    assert not engine.parties_are_homogeneous(ext, mixed, shapes)


def test_fewshot_phase5_reuses_cluster_pseudo_labels(splits):
    """Alg. 2's phase ⑤' reuses the step-③ gradient-cluster pseudo-labels
    Ŷ_o^k for the overlap rows — re-predicting with the drifted local head
    is NOT guaranteed to agree and only survives behind the legacy flag."""
    split = splits[0]
    one = run_one_shot(jax.random.PRNGKey(0), split, _ext(), _SSL, _FAST)
    client = one.clients[0]
    pseudo = one.diagnostics["pseudo_labels"][0]
    x_o, x_u = split.aligned[0], split.unaligned[0]
    n_o = x_o.shape[0]

    y_paper = fewshot_phase5_labels(client, x_o, x_u, pseudo,
                                    relabel_overlap=False)
    assert bool(jnp.all(y_paper[:n_o] == pseudo))
    y_legacy = fewshot_phase5_labels(client, x_o, x_u, pseudo,
                                     relabel_overlap=True)
    assert bool(jnp.all(y_legacy[:n_o] == client.predict(x_o)))
    # pool rows are the local model's predictions either way
    assert bool(jnp.all(y_paper[n_o:] == client.predict(x_u)))
    # the drift is real on this task: the two labelings disagree somewhere,
    # which is exactly why "they agree by construction" was wrong
    assert int(jnp.sum(y_paper[:n_o] != y_legacy[:n_o])) > 0
