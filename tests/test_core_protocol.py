"""End-to-end protocol tests on small synthetic tabular VFL tasks."""
import jax
import pytest

from repro.core import (CommLedger, IterativeConfig, ProtocolConfig, SSLConfig,
                        run_fedbcd, run_fedcvt, run_few_shot, run_one_shot,
                        run_vanilla)
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


@pytest.fixture(scope="module")
def split():
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 1200)
    return make_vfl_partition(x, y, overlap_size=128, feature_sizes=[10, 13],
                              seed=1)


def _extractors():
    return [make_mlp_extractor(rep_dim=16, hidden=(32,)) for _ in range(2)]


_SSL = [SSLConfig(modality="tabular")] * 2
_FAST = ProtocolConfig(client_epochs=2, server_epochs=5)


def test_one_shot_end_to_end(split):
    res = run_one_shot(jax.random.PRNGKey(1), split, _extractors(), _SSL, _FAST)
    assert res.metric_name == "auc"
    assert res.metric > 0.6                      # far better than chance
    # THE paper claim: exactly 3 communication times per client
    assert res.ledger.comm_times() == 3
    assert all(p > 0.5 for p in res.diagnostics["kmeans_purity"])


def test_few_shot_end_to_end(split):
    res = run_few_shot(jax.random.PRNGKey(1), split, _extractors(), _SSL, _FAST)
    assert res.metric > 0.6
    # THE paper claim: exactly 5 communication times per client
    assert res.ledger.comm_times() == 5


def test_one_shot_beats_vanilla_with_limited_overlap():
    """Table 1's headline ordering under limited overlap: one-shot uses the
    unaligned pools and outperforms iterative VFL on the tiny overlap, at a
    fraction of the communication.

    xfail since the seed on the easy credit task (the iterative baseline
    fits a 128-row overlap within its budget); restored by pointing it at
    the registry's hardened scenario — N_o=32 on ``hard/overlap-32``, where
    a supervised fit of 32 noisy rows cannot compete with local SSL over
    the party-private pools. Margins validated at +0.04…+0.09 over seeds
    0-3; the assert keeps a paper-style strict margin with headroom."""
    from repro import scenarios

    bundle = scenarios.build("hard/overlap-32", seed=0)
    spec = bundle.spec
    one = run_one_shot(
        jax.random.PRNGKey(0), bundle.split, bundle.extractors,
        bundle.ssl_cfgs,
        ProtocolConfig(client_epochs=spec.budget("client_epochs", 60),
                       server_epochs=spec.budget("server_epochs", 40)))
    van = run_vanilla(jax.random.PRNGKey(0), bundle.split, bundle.extractors,
                      bundle.ssl_cfgs,
                      IterativeConfig(iterations=spec.budget("iterations", 300)))
    assert one.metric >= van.metric + 0.02      # strictly better, with margin
    assert one.ledger.total_bytes() * 100 <= van.ledger.total_bytes()
    assert one.ledger.comm_times() < van.ledger.comm_times() / 10


def test_vanilla_comm_accounting(split):
    res = run_vanilla(jax.random.PRNGKey(3), split, _extractors(), _SSL,
                      IterativeConfig(iterations=50))
    # 2 events per iteration per client (reps up, grads down)
    assert res.ledger.comm_times() == 100
    expected = 50 * 2 * 2 * 32 * 16 * 4       # iters × dirs × clients × B × rep × f32
    assert res.ledger.total_bytes() == expected


def test_fedbcd_reduces_rounds_by_q(split):
    cfg = IterativeConfig(iterations=50, fedbcd_q=5)
    res = run_fedbcd(jax.random.PRNGKey(4), split, _extractors(), _SSL, cfg)
    assert res.metric > 0.5
    assert res.ledger.comm_times() == 2 * 50 // 5      # Q× fewer rounds
    assert res.diagnostics["Q"] == 5


def test_fedcvt_runs_and_counts(split):
    res = run_fedcvt(jax.random.PRNGKey(5), split, _extractors(), _SSL,
                     IterativeConfig(iterations=30))
    assert res.metric > 0.5
    # fedcvt ships overlap+unaligned reps → 2× vanilla bytes per iteration
    assert res.ledger.total_bytes() == 30 * 2 * 2 * 2 * 32 * 16 * 4


def test_ledger_round_bundling():
    led = CommLedger()
    r = led.next_round()
    led.log_bytes(0, "up", "a", 100, round=r)
    led.log_bytes(0, "up", "b", 50, round=r)   # same message
    led.log_bytes(0, "down", "c", 10)
    assert led.comm_times(0) == 2
    assert led.total_bytes() == 160


def test_protocol_k3_parties():
    """K-ary generalization: 3 parties."""
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 900)
    split = make_vfl_partition(x, y, overlap_size=96, feature_sizes=[8, 8, 7],
                               num_parties=3, seed=2)
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(3)]
    res = run_one_shot(jax.random.PRNGKey(1), split, ext,
                       [SSLConfig(modality="tabular")] * 3, _FAST)
    assert res.metric > 0.55
    assert res.ledger.comm_times() == 3
