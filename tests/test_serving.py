"""The serving subsystem's contracts (ISSUE 7, DESIGN.md §13).

* artifact save/load round-trips parameters AND apply identity;
* the fused batched forward matches the unbatched reference at 1e-5,
  through padding, chunking, and heterogeneous party zoos;
* the fused program is session-cached under a width-free key: serving at
  new batch shapes adds ZERO fresh "serving"-domain misses;
* the runner registry is the ONLY dispatch surface (`_batched_impls`
  deleted) and still rejects per-seed state kwargs;
* the typed row builders validate shape and feed both gates.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (ExtractorSpec, TrainedVFLModel, load_artifact,
                              save_artifact)
from repro.checkpoint.artifact import from_state
from repro.core import rows as result_rows
from repro.core import runners as runner_registry
from repro.core.protocol import ProtocolConfig, run_one_shot, run_seeds
from repro.data import make_tabular_credit, make_vfl_partition
from repro.engine import session_cache_stats
from repro.engine.local_ssl import PartyParams
from repro.launch import batching
from repro.launch.vfl_serve import KernelRouter, ServingEngine
from repro.models.extractors import make_classifier, make_mlp_extractor

_FAST = ProtocolConfig(client_epochs=2, server_epochs=3)


# ---------------------------------------------------------------- fixtures
def _split(seed=0):
    x, y = make_tabular_credit(jax.random.PRNGKey(1000 + seed), 700)
    return make_vfl_partition(x[:, :22], y, overlap_size=64,
                              feature_sizes=[11, 11], seed=seed)


def _mk_artifact(seed=0, with_split=True):
    """Train one fast one-shot run on a synthetic homogeneous scenario and
    export it through the real scenario registry."""
    from repro import scenarios

    spec = scenarios.get("hard/overlap-32")
    bundle = scenarios.build(spec, seed=seed, smoke=True)
    res = run_one_shot(jax.random.PRNGKey(seed), bundle.split,
                       bundle.extractors, bundle.ssl_cfgs, _FAST)
    art = res.to_artifact(spec, cfg=_FAST,
                          split=bundle.split if with_split else None)
    return art, bundle


@pytest.fixture(scope="module")
def trained():
    return _mk_artifact(seed=0)


# ----------------------------------------------------------- artifact layer
def test_artifact_roundtrip_parity(trained, tmp_path):
    art, bundle = trained
    save_artifact(str(tmp_path / "art"), art)
    art2 = load_artifact(str(tmp_path / "art"))
    assert art2.scenario == art.scenario
    assert art2.extractor_specs == art.extractor_specs
    assert art2.feature_shapes == art.feature_shapes
    assert art2.version == art.version
    assert art2.protocol_config().client_epochs == _FAST.client_epochs
    xs = [x[:9] for x in bundle.split.aligned]
    np.testing.assert_allclose(np.asarray(art.predict_logits(xs)),
                               np.asarray(art2.predict_logits(xs)),
                               atol=1e-6)
    # overlap reps (Eq. 10 keys/values) survive the round trip
    for a, b in zip(art.overlap_reps, art2.overlap_reps):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_artifact_matches_training_server_forward(trained):
    """to_artifact must export EXACTLY the trained forward: the artifact's
    reference logits equal the live server's on the aligned rows."""
    art, bundle = trained
    from repro.core.protocol import run_one_shot as _  # noqa: F401

    # recompute through the live objects
    res = run_one_shot(jax.random.PRNGKey(0), bundle.split,
                       bundle.extractors, bundle.ssl_cfgs, _FAST)
    xs = [x[:16] for x in bundle.split.aligned]
    reps = [c.extract(x) for c, x in zip(res.clients, xs)]
    live = res.server.predict_logits(reps)
    np.testing.assert_allclose(np.asarray(art.predict_logits(xs)),
                               np.asarray(live), atol=1e-5)


def test_artifact_version_gate(tmp_path):
    art, _ = _mk_artifact(seed=1, with_split=True)
    d = str(tmp_path / "art")
    save_artifact(d, art)
    import json
    import numpy as onp

    # forge a future-version artifact: the loader must refuse, not guess
    path = d + "/ckpt_00000000.npz"
    blob = dict(onp.load(path))
    meta = json.loads(bytes(blob["__meta__"]).decode())
    meta["artifact_version"] = 99
    blob["__meta__"] = onp.frombuffer(json.dumps(meta).encode(),
                                      dtype=onp.uint8)
    onp.savez(path, **blob)
    with pytest.raises(ValueError, match="newer than supported"):
        load_artifact(d)


def test_from_state_without_split_recovers_mlp_shapes(trained):
    art, bundle = trained
    res = run_one_shot(jax.random.PRNGKey(0), bundle.split,
                       bundle.extractors, bundle.ssl_cfgs, _FAST)
    from repro import scenarios

    art2 = from_state(res.clients, res.server,
                      scenarios.get("hard/overlap-32"), cfg=_FAST)
    assert art2.feature_shapes == art.feature_shapes
    assert art2.overlap_reps is None


# ------------------------------------------------------------ fused forward
def test_batched_matches_sequential_1e5(trained, tmp_path):
    """The acceptance bar: batched predictions from a LOADED artifact match
    the unbatched reference forward at 1e-5 — across chunking and
    padding."""
    art, bundle = trained
    save_artifact(str(tmp_path / "art"), art)
    engine = ServingEngine(load_artifact(str(tmp_path / "art")), capacity=8)
    xs = [x[:21] for x in bundle.split.aligned]      # 3 chunks, last ragged
    fused = engine.predict_logits(xs)
    # sequential: one row at a time through the unbatched oracle
    rows = [art.predict_logits([x[i:i + 1] for x in xs])
            for i in range(21)]
    np.testing.assert_allclose(np.asarray(fused),
                               np.asarray(jnp.concatenate(rows, axis=0)),
                               atol=1e-5)


def test_fused_uses_vmap_party_fold_when_homogeneous(trained):
    art, _ = trained
    assert art.parties_are_homogeneous
    engine = ServingEngine(art, capacity=4)
    assert jax.tree_util.tree_structure(engine._ext_params) \
        == jax.tree_util.tree_structure(art.client_params[0].extractor)
    stacked_leaf = jax.tree_util.tree_leaves(engine._ext_params)[0]
    assert stacked_leaf.shape[0] == art.num_parties


def test_heterogeneous_parties_compose_and_match(tmp_path):
    """Unequal per-party feature widths force the composition path; parity
    must hold there too."""
    split = _split(seed=2)
    # two different MLP architectures ⇒ not homogeneous
    exts = [make_mlp_extractor(rep_dim=8, hidden=(16,)),
            make_mlp_extractor(rep_dim=8, hidden=(12, 12))]
    specs = (ExtractorSpec(kind="mlp", rep_dim=8, hidden=(16,)),
             ExtractorSpec(kind="mlp", rep_dim=8, hidden=(12, 12)))
    key = jax.random.PRNGKey(0)
    client_params = []
    for e, x in zip(exts, split.aligned):
        p = e.init(key, x[:2])
        head = make_classifier(2).init(key, e.apply(p, x[:1]))
        client_params.append(PartyParams(p, head))
    clf = make_classifier(2)
    server_params = clf.init(
        key, jnp.zeros((1, sum(e.rep_dim for e in exts))))
    art = TrainedVFLModel(
        scenario="synthetic/hetero", num_classes=2,
        feature_shapes=tuple(tuple(x.shape[1:]) for x in split.aligned),
        extractor_specs=specs, client_params=client_params,
        server_params=server_params)
    assert not art.parties_are_homogeneous
    d = str(tmp_path / "het")
    save_artifact(d, art)
    art2 = load_artifact(d)
    engine = ServingEngine(art2, capacity=8)
    xs = [x[:13] for x in split.aligned]
    np.testing.assert_allclose(np.asarray(engine.predict_logits(xs)),
                               np.asarray(art.predict_logits(xs)),
                               atol=1e-5)


def test_zero_fresh_serving_misses_after_first_shape(trained):
    """The recompile-regression contract: ONE serving-session build per
    deployed model — new capacities, new engines, new batch sizes all
    re-serve it."""
    art, bundle = trained
    xs = [x[:3] for x in bundle.split.aligned]
    ServingEngine(art, capacity=4).predict_logits(xs)     # first shape
    misses0 = session_cache_stats("serving")["misses"]
    for capacity in (1, 16, 64):
        engine = ServingEngine(art, capacity=capacity)
        engine.predict_logits([x[:capacity] for x in bundle.split.aligned])
    assert session_cache_stats("serving")["misses"] == misses0
    assert session_cache_stats("serving")["hits"] >= 3


def test_partial_party_queries_serve_via_estimation(trained):
    art, bundle = trained
    engine = ServingEngine(art, capacity=8)
    logits = engine.predict_logits_partial(bundle.split.aligned[0][:6], 0)
    assert logits.shape == (6, art.num_classes)
    art_bare = dataclasses.replace(art)
    art_bare.overlap_reps = None
    with pytest.raises(ValueError, match="overlap_reps"):
        ServingEngine(art_bare, capacity=8).predict_logits_partial(
            bundle.split.aligned[0][:6], 0)


def test_kernel_router_roofline_rules():
    cpu = KernelRouter(backend="cpu", interpret=True)
    assert not cpu.use_sdpa(1 << 20, 1 << 10, 64)      # never under interpret
    assert not cpu.use_rmsnorm(4096, 4096)
    tpu = KernelRouter(backend="tpu", interpret=False)
    assert tpu.use_sdpa(1 << 12, 1 << 10, 64)          # 16 MB score matrix
    assert not tpu.use_sdpa(64, 32, 64)                # XLA fuses small
    # the batched-grid width scales the roofline: one slice of a K−1-wide
    # partial-party launch sits under the crossover, the whole launch is
    # the real score volume and clears it
    assert not tpu.use_sdpa(1 << 10, 1 << 9, 64)             # 2 MB slice
    assert tpu.use_sdpa(1 << 10, 1 << 9, 64, batch=3)        # 6 MB launch
    assert not cpu.use_sdpa(1 << 10, 1 << 9, 64, batch=64)   # interpret: never
    assert tpu.use_rmsnorm(2048, 4096)                 # ops.py's own example
    assert not tpu.use_rmsnorm(8, 128)
    assert tpu.use_decode_attention(8192)
    assert not tpu.use_decode_attention(512)


# ----------------------------------------------------------------- batcher
def test_masked_batcher_pads_and_masks():
    xs = (jnp.ones((3, 5)), jnp.ones((3, 2)))
    b = batching.pad_to_capacity(xs, 8)
    assert b.xs[0].shape == (8, 5) and b.xs[1].shape == (8, 2)
    assert b.n == 3 and int(b.mask.sum()) == 3
    with pytest.raises(ValueError, match="exceeds capacity"):
        batching.pad_to_capacity((jnp.ones((9, 2)),), 8)
    with pytest.raises(ValueError, match="same rows"):
        batching.pad_to_capacity((jnp.ones((3, 2)), jnp.ones((4, 2))), 8)
    chunks = batching.chunk_requests((jnp.ones((10, 2)),), 4)
    assert [c[0].shape[0] for c in chunks] == [4, 4, 2]


def test_latency_recorder_percentiles():
    rec = batching.LatencyRecorder()
    for ms in range(1, 101):
        rec.record(ms / 1e3, rows=2)
    s = rec.summary()
    assert s["batches"] == 100 and s["rows"] == 200
    assert 50.0 <= s["p50_ms"] <= 51.0
    assert 99.0 <= s["p99_ms"] <= 100.0
    assert s["rows_per_s"] > 0


# ------------------------------------------------------- registry + rows
def test_registry_is_the_only_dispatch_surface():
    from repro.core import protocol

    assert not hasattr(protocol, "_batched_impls")
    assert not hasattr(protocol, "_reject_stateful_kwargs")
    # every catalog method the frontier drives resolves
    for name in ("one_shot", "few_shot", "iterative", "fedcvt"):
        entry = runner_registry.get(name)
        assert callable(entry.runner) and callable(entry.seeds_impl)
        assert entry.kind in ("protocol", "iterative")
    # alias and canonical name resolve to one entry
    assert runner_registry.get("iterative") is runner_registry.get("vanilla")
    # runner-callable lookup agrees with name lookup
    e = runner_registry.get("one_shot")
    assert runner_registry.resolve(e.runner) is e
    with pytest.raises(KeyError, match="unknown runner"):
        runner_registry.get("nope")


def test_run_seeds_still_rejects_state_kwargs_via_registry():
    split = _split(seed=3)
    exts = [[make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]]
    from repro.core.ssl import SSLConfig

    with pytest.raises(ValueError, match="state kwargs"):
        run_seeds(run_one_shot, [jax.random.PRNGKey(0)], [split], exts,
                  [[SSLConfig(), SSLConfig()]], _FAST, ledger=object())


def test_row_builders_validate_and_unify():
    row = result_rows.serving_row("p50_ms", 1.25, batch=64, rows_per_s=9.0)
    assert row["kind"] == "serving" and row["metric_name"] == "p50_ms"
    assert row["metric"] == 1.25 and row["batch"] == 64
    with pytest.raises(ValueError, match="shadow"):
        result_rows.serving_row("p50_ms", 1.0, comm_bytes=7)
    with pytest.raises(ValueError, match="kind"):
        result_rows.ResultRow(kind="bogus", metric_name="x", metric=0.0)

    class FakeResult:
        metric_name = "auc"
        metric = 0.9
        diagnostics = {"engine_path": "vmap", "seed_fold": 2}

        class ledger:  # noqa: N801 — duck-typed CommLedger
            @staticmethod
            def total_bytes():
                return 123

            @staticmethod
            def comm_times():
                return 3

    trow = result_rows.training_row(FakeResult(), scenario="s", seed=0)
    assert trow["kind"] == "train" and trow["comm_bytes"] == 123
    assert trow["engine_path"] == "vmap" and trow["scenario"] == "s"
    with pytest.raises(ValueError, match="collide"):
        result_rows.training_row(FakeResult(), engine_path="python")


def test_serving_gate_consumes_typed_rows(tmp_path):
    import json
    import sys

    sys.path.insert(0, str(__import__("pathlib").Path(__file__).parent.parent))
    from benchmarks import serving as serving_bench

    base = tmp_path / "base.json"
    base.write_text(json.dumps({
        "parity_atol": 1e-5,
        "max_p50_ms": {"1": 10.0},
        "min_rows_per_s": {"1": 100.0},
    }))
    ok = result_rows.serving_row("p50_ms", 1.0, batch=1, rows_per_s=500.0,
                                 parity_max_abs=1e-7, cache_misses=1,
                                 first_shape=True)
    assert serving_bench.check_serving_gate([ok], str(base)) == []
    bad = result_rows.serving_row("p50_ms", 99.0, batch=1, rows_per_s=1.0,
                                  parity_max_abs=1e-2, cache_misses=2,
                                  first_shape=False)
    problems = serving_bench.check_serving_gate([bad], str(base))
    assert len(problems) == 4        # parity, recompile, p50, throughput
    assert serving_bench.check_serving_gate([], str(base)) \
        == ["no serving rows to gate"]
