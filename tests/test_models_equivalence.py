"""Prefill ↔ sequential-decode equivalence: the strongest correctness check
on cache handling, rope offsets, SSD vs recurrence, MLA absorption, the
shared hybrid block, and MoE drop-free decode routing."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import all_configs, get_config
from repro.launch import specs as SP
from repro.models.model_zoo import build_model

ARCHS = sorted(all_configs())


def _float_cfg(cfg):
    cfg = dataclasses.replace(cfg.reduced(), activation_dtype="float32")
    if cfg.moe:
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    return cfg


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_equals_sequential_decode(arch):
    cfg = _float_cfg(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    S = 8
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, S), 0, cfg.vocab_size)

    batch_pf = {"tokens": toks}
    if cfg.family == "audio":
        emb = 0.02 * jax.random.normal(jax.random.PRNGKey(4),
                                       (2, cfg.prefix_tokens, cfg.d_model))
        batch_pf["embeds"] = emb
    logits_full = model.prefill_fn(params, batch_pf)

    cache = SP.zeros_like_spec(model.cache_shapes(2, S))
    cache = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, cache)
    if cfg.family == "audio":
        from repro.models.model_zoo import _encode
        cache["enc_out"] = _encode(params, cfg, emb.astype(jnp.float32))
    for t in range(S):
        b = {"token": toks[:, t:t + 1], "pos": jnp.full((2, 1), t, jnp.int32)}
        logits_dec, cache = model.decode_fn(params, cache, b)

    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    rel = err / (float(jnp.max(jnp.abs(logits_full))) + 1e-9)
    assert rel < 2e-5, f"{arch}: prefill/decode diverge (rel {rel:.2e})"


def test_sliding_window_decode_matches_windowed_prefill():
    """The long_500k variant: ring-buffer cache + window masking must equal
    the windowed blocked-scan prefill."""
    cfg = dataclasses.replace(get_config("gemma-7b").reduced(),
                              activation_dtype="float32", attn_window=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    S = 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, S), 0, cfg.vocab_size)
    logits_full = model.prefill_fn(params, {"tokens": toks})
    # ring buffer sized to the window
    cache = SP.zeros_like_spec(model.cache_shapes(1, S))
    cache = jax.tree_util.tree_map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x, cache)
    for t in range(S):
        b = {"token": toks[:, t:t + 1], "pos": jnp.full((1, 1), t, jnp.int32)}
        logits_dec, cache = model.decode_fn(params, cache, b)
    err = float(jnp.max(jnp.abs(logits_full - logits_dec)))
    rel = err / (float(jnp.max(jnp.abs(logits_full))) + 1e-9)
    assert rel < 2e-5, f"window decode diverges (rel {rel:.2e})"


def test_ssd_chunk_size_invariance():
    """SSD output must not depend on the chunk size (property of the
    chunked state-passing identity)."""
    from repro.models.ssm import ssd_chunked

    key = jax.random.PRNGKey(0)
    b, s, h, p, n = 2, 32, 4, 8, 16
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(1), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(2), (h,)))
    bm = jax.random.normal(jax.random.PRNGKey(3), (b, s, n))
    cm = jax.random.normal(jax.random.PRNGKey(4), (b, s, n))
    y8, f8 = ssd_chunked(x, dt, a, bm, cm, chunk=8)
    y16, f16 = ssd_chunked(x, dt, a, bm, cm, chunk=16)
    y32, f32_ = ssd_chunked(x, dt, a, bm, cm, chunk=32)
    assert jnp.allclose(y8, y16, atol=1e-4)
    assert jnp.allclose(y8, y32, atol=1e-4)
    assert jnp.allclose(f8, f16, atol=1e-4)


def test_ssd_matches_naive_recurrence():
    from repro.models.ssm import ssd_chunked

    b, s, h, p, n = 1, 16, 2, 4, 8
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(jax.random.PRNGKey(8), (b, s, h)))
    a = -jnp.exp(jax.random.normal(jax.random.PRNGKey(9), (h,)))
    bm = jax.random.normal(jax.random.PRNGKey(10), (b, s, n))
    cm = jax.random.normal(jax.random.PRNGKey(11), (b, s, n))
    y, final = ssd_chunked(x, dt, a, bm, cm, chunk=8)

    # naive per-step recurrence
    state = jnp.zeros((b, h, p, n))
    ys = []
    for t in range(s):
        da = jnp.exp(dt[:, t] * a[None, :])                       # (b,h)
        bx = jnp.einsum("bn,bhp,bh->bhpn", bm[:, t], x[:, t], dt[:, t])
        state = state * da[..., None, None] + bx
        ys.append(jnp.einsum("bn,bhpn->bhp", cm[:, t], state))
    y_naive = jnp.stack(ys, axis=1)
    assert jnp.allclose(y, y_naive, atol=1e-4)
    assert jnp.allclose(final, state, atol=1e-4)
