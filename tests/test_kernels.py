"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as dec_ops, ref as dec_ref
from repro.kernels.kmeans import ops as km_ops, ref as km_ref
from repro.kernels.sdpa_estimator import ops as sdpa_ops, ref as sdpa_ref

requires_tpu = pytest.mark.skipif(
    jax.default_backend() != "tpu",
    reason="compiled (non-interpret) Pallas grids need a TPU backend")


# ----------------------------------------------------------------- kmeans --
@pytest.mark.parametrize("n,d,c", [
    (100, 32, 10), (257, 130, 7), (1024, 128, 10), (33, 5, 3),
    (8, 1, 2), (512, 256, 100),
])
def test_kmeans_assign_matches_ref(n, d, c):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + d + c))
    x = jax.random.normal(k1, (n, d))
    cen = jax.random.normal(k2, (c, d))
    assert np.array_equal(np.asarray(km_ops.kmeans_assign(x, cen)),
                          np.asarray(km_ref.kmeans_assign(x, cen)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 16)).astype(dtype)
    cen = jax.random.normal(jax.random.PRNGKey(1), (4, 16)).astype(dtype)
    got = km_ops.kmeans_assign(x, cen)
    want = km_ref.kmeans_assign(x, cen)
    assert float(np.mean(np.asarray(got) == np.asarray(want))) > 0.98


# ------------------------------------------------------------------- sdpa --
@pytest.mark.parametrize("nu,no,d,db", [
    (100, 50, 32, 48), (513, 200, 128, 128), (7, 3, 5, 9),
    (1000, 64, 64, 96), (256, 256, 256, 32),
])
def test_sdpa_matches_ref(nu, no, d, db):
    ks = jax.random.split(jax.random.PRNGKey(nu + no), 3)
    hu = jax.random.normal(ks[0], (nu, d))
    hoa = jax.random.normal(ks[1], (no, d))
    hob = jax.random.normal(ks[2], (no, db))
    got = sdpa_ops.sdpa_estimate(hu, hoa, hob)
    want = sdpa_ref.sdpa_estimate(hu, hoa, hob)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sdpa_dtypes(dtype):
    hu = jax.random.normal(jax.random.PRNGKey(0), (65, 32)).astype(dtype)
    hoa = jax.random.normal(jax.random.PRNGKey(1), (33, 32)).astype(dtype)
    hob = jax.random.normal(jax.random.PRNGKey(2), (33, 16)).astype(dtype)
    got = sdpa_ops.sdpa_estimate(hu, hoa, hob)
    want = sdpa_ref.sdpa_estimate(hu, hoa, hob)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-2, rtol=3e-2)


def test_sdpa_large_asymmetric():
    """The few-shot regime: N_u ≫ N_o."""
    hu = jax.random.normal(jax.random.PRNGKey(0), (4096, 128))
    hoa = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
    hob = jax.random.normal(jax.random.PRNGKey(2), (128, 128))
    got = sdpa_ops.sdpa_estimate(hu, hoa, hob)
    want = sdpa_ref.sdpa_estimate(hu, hoa, hob)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------- batched grids (DESIGN.md §15) --
def _km_batch(b, n, d, c, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed + b + n))
    return (jax.random.normal(k1, (b, n, d)),
            jax.random.normal(k2, (b, c, d)))


def _sdpa_batch(b, nu, no, d, db, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed + b + nu), 3)
    return (jax.random.normal(ks[0], (b, nu, d)),
            jax.random.normal(ks[1], (b, no, d)),
            jax.random.normal(ks[2], (b, no, db)))


@pytest.mark.parametrize("b,n,d,c", [
    (1, 100, 32, 10), (5, 300, 17, 10), (3, 257, 130, 7), (2, 8, 1, 2),
])
def test_kmeans_batched_grid_matches_vmapped_ref(b, n, d, c):
    """One (B, N/BN) grid launch ≡ jax.vmap of the jnp oracle, bit-equal."""
    x, cen = _km_batch(b, n, d, c)
    got = km_ops.kmeans_assign_batched(x, cen)
    want = jax.vmap(km_ref.kmeans_assign)(x, cen)
    assert got.shape == (b, n)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("b,n,d,c", [(4, 200, 24, 6)])
def test_kmeans_batched_grid_matches_per_call_kernel(b, n, d, c):
    """Batched grid ≡ B width-1 kernel launches (the fold changes the grid,
    never the program each instance runs)."""
    x, cen = _km_batch(b, n, d, c, seed=7)
    got = km_ops.kmeans_assign_batched(x, cen)
    per = np.stack([np.asarray(km_ops.kmeans_assign(x[i], cen[i]))
                    for i in range(b)])
    assert np.array_equal(np.asarray(got), per)


def test_kmeans_width1_is_batched_grid():
    """The single-entry public op IS the width-1 batched grid."""
    x, cen = _km_batch(1, 150, 20, 5, seed=3)
    a = km_ops.kmeans_assign(x[0], cen[0])
    b_ = km_ops.kmeans_assign_batched(x, cen)[0]
    assert np.array_equal(np.asarray(a), np.asarray(b_))


@pytest.mark.parametrize("b,nu,no,d,db", [
    (1, 100, 50, 32, 48), (4, 333, 70, 19, 23), (2, 513, 200, 128, 128),
    (3, 7, 3, 5, 9),
])
def test_sdpa_batched_grid_matches_vmapped_ref(b, nu, no, d, db):
    """One (B, N_u/BU, N_o/BO) grid launch ≡ jax.vmap of the jnp oracle."""
    hu, hoa, hob = _sdpa_batch(b, nu, no, d, db)
    got = sdpa_ops.sdpa_estimate_batched(hu, hoa, hob)
    want = jax.vmap(sdpa_ref.sdpa_estimate)(hu, hoa, hob)
    assert got.shape == (b, nu, db)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


def test_sdpa_batched_grid_matches_per_call_kernel():
    """Batched grid ≡ B width-1 kernel launches, bit-equal (identical
    per-instance program, identical padding plan)."""
    b, nu, no, d, db = 3, 120, 40, 16, 24
    hu, hoa, hob = _sdpa_batch(b, nu, no, d, db, seed=11)
    got = np.asarray(sdpa_ops.sdpa_estimate_batched(hu, hoa, hob))
    per = np.stack([np.asarray(sdpa_ops.sdpa_estimate(hu[i], hoa[i], hob[i]))
                    for i in range(b)])
    assert np.array_equal(got, per)


def test_batched_grids_vmap_directly():
    """jax.vmap over the batched public entries composes (the stacked-axis
    contract the engine's mesh sharding relies on): vmapping the width-1
    call must agree with the native batched grid."""
    x, cen = _km_batch(3, 64, 12, 4, seed=5)
    native = km_ops.kmeans_assign_batched(x, cen)
    vmapped = jax.vmap(km_ops.kmeans_assign)(x, cen)
    assert np.array_equal(np.asarray(native), np.asarray(vmapped))


@requires_tpu
def test_kmeans_batched_grid_compiled_mode(monkeypatch):
    """The same parity with interpret forced OFF — the Mosaic-compiled
    grid, not the interpreter (TPU only)."""
    monkeypatch.setattr(km_ops, "interpret_mode", lambda: False)
    x, cen = _km_batch(4, 300, 64, 10)
    got = km_ops.kmeans_assign_batched(x, cen)
    want = jax.vmap(km_ref.kmeans_assign)(x, cen)
    assert np.array_equal(np.asarray(got), np.asarray(want))


@requires_tpu
def test_sdpa_batched_grid_compiled_mode(monkeypatch):
    monkeypatch.setattr(sdpa_ops, "interpret_mode", lambda: False)
    hu, hoa, hob = _sdpa_batch(4, 512, 128, 64, 64)
    got = sdpa_ops.sdpa_estimate_batched(hu, hoa, hob)
    want = jax.vmap(sdpa_ref.sdpa_estimate)(hu, hoa, hob)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


# ------------------------------------------------------------ decode attn --
@pytest.mark.parametrize("b,h,hkv,s,dh", [
    (2, 8, 2, 128, 64), (1, 16, 16, 300, 128), (3, 12, 4, 1024, 32),
    (2, 4, 1, 77, 80),
])
def test_decode_attention_matches_ref(b, h, hkv, s, dh):
    ks = jax.random.split(jax.random.PRNGKey(b * h + s), 3)
    q = jax.random.normal(ks[0], (b, h, dh))
    kc = jax.random.normal(ks[1], (b, hkv, s, dh))
    vc = jax.random.normal(ks[2], (b, hkv, s, dh))
    got = dec_ops.decode_attention(q, kc, vc)
    want = dec_ref.decode_attention(q, kc, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_bf16_cache():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
    kc = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 256, 64)).astype(jnp.bfloat16)
    vc = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 256, 64)).astype(jnp.bfloat16)
    got = dec_ops.decode_attention(q, kc, vc)
    want = dec_ref.decode_attention(q, kc, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-2, rtol=3e-2)
