"""Per-kernel shape/dtype sweeps against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import ops as dec_ops, ref as dec_ref
from repro.kernels.kmeans import ops as km_ops, ref as km_ref
from repro.kernels.sdpa_estimator import ops as sdpa_ops, ref as sdpa_ref


# ----------------------------------------------------------------- kmeans --
@pytest.mark.parametrize("n,d,c", [
    (100, 32, 10), (257, 130, 7), (1024, 128, 10), (33, 5, 3),
    (8, 1, 2), (512, 256, 100),
])
def test_kmeans_assign_matches_ref(n, d, c):
    k1, k2 = jax.random.split(jax.random.PRNGKey(n + d + c))
    x = jax.random.normal(k1, (n, d))
    cen = jax.random.normal(k2, (c, d))
    assert np.array_equal(np.asarray(km_ops.kmeans_assign(x, cen)),
                          np.asarray(km_ref.kmeans_assign(x, cen)))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_dtypes(dtype):
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 16)).astype(dtype)
    cen = jax.random.normal(jax.random.PRNGKey(1), (4, 16)).astype(dtype)
    got = km_ops.kmeans_assign(x, cen)
    want = km_ref.kmeans_assign(x, cen)
    assert float(np.mean(np.asarray(got) == np.asarray(want))) > 0.98


# ------------------------------------------------------------------- sdpa --
@pytest.mark.parametrize("nu,no,d,db", [
    (100, 50, 32, 48), (513, 200, 128, 128), (7, 3, 5, 9),
    (1000, 64, 64, 96), (256, 256, 256, 32),
])
def test_sdpa_matches_ref(nu, no, d, db):
    ks = jax.random.split(jax.random.PRNGKey(nu + no), 3)
    hu = jax.random.normal(ks[0], (nu, d))
    hoa = jax.random.normal(ks[1], (no, d))
    hob = jax.random.normal(ks[2], (no, db))
    got = sdpa_ops.sdpa_estimate(hu, hoa, hob)
    want = sdpa_ref.sdpa_estimate(hu, hoa, hob)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_sdpa_dtypes(dtype):
    hu = jax.random.normal(jax.random.PRNGKey(0), (65, 32)).astype(dtype)
    hoa = jax.random.normal(jax.random.PRNGKey(1), (33, 32)).astype(dtype)
    hob = jax.random.normal(jax.random.PRNGKey(2), (33, 16)).astype(dtype)
    got = sdpa_ops.sdpa_estimate(hu, hoa, hob)
    want = sdpa_ref.sdpa_estimate(hu, hoa, hob)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-2, rtol=3e-2)


def test_sdpa_large_asymmetric():
    """The few-shot regime: N_u ≫ N_o."""
    hu = jax.random.normal(jax.random.PRNGKey(0), (4096, 128))
    hoa = jax.random.normal(jax.random.PRNGKey(1), (128, 128))
    hob = jax.random.normal(jax.random.PRNGKey(2), (128, 128))
    got = sdpa_ops.sdpa_estimate(hu, hoa, hob)
    want = sdpa_ref.sdpa_estimate(hu, hoa, hob)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------ decode attn --
@pytest.mark.parametrize("b,h,hkv,s,dh", [
    (2, 8, 2, 128, 64), (1, 16, 16, 300, 128), (3, 12, 4, 1024, 32),
    (2, 4, 1, 77, 80),
])
def test_decode_attention_matches_ref(b, h, hkv, s, dh):
    ks = jax.random.split(jax.random.PRNGKey(b * h + s), 3)
    q = jax.random.normal(ks[0], (b, h, dh))
    kc = jax.random.normal(ks[1], (b, hkv, s, dh))
    vc = jax.random.normal(ks[2], (b, hkv, s, dh))
    got = dec_ops.decode_attention(q, kc, vc)
    want = dec_ref.decode_attention(q, kc, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_decode_attention_bf16_cache():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 64))
    kc = jax.random.normal(jax.random.PRNGKey(1), (2, 2, 256, 64)).astype(jnp.bfloat16)
    vc = jax.random.normal(jax.random.PRNGKey(2), (2, 2, 256, 64)).astype(jnp.bfloat16)
    got = dec_ops.decode_attention(q, kc, vc)
    want = dec_ref.decode_attention(q, kc, vc)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-2, rtol=3e-2)
