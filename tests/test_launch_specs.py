"""input_specs coverage: every (arch × shape) pair yields a well-formed spec
tree (the dry-run's contract), plus decode-cache consistency."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import INPUT_SHAPES, all_configs, get_config
from repro.launch import specs as SP
from repro.launch.dryrun import config_for
from repro.models.model_zoo import build_model

ARCHS = sorted(all_configs())


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape_name", list(INPUT_SHAPES))
def test_specs_shapes(arch, shape_name):
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for(arch, shape)
    if shape.kind == "train":
        spec = SP.train_specs(cfg, shape)
        assert spec["tokens"].shape[0] == shape.global_batch
        total = spec["tokens"].shape[1] + (
            spec["embeds"].shape[1] if "embeds" in spec else 0)
        assert total == shape.seq_len
        assert spec["labels"].shape == spec["tokens"].shape
    elif shape.kind == "prefill":
        spec = SP.prefill_specs(cfg, shape)
        assert "labels" not in spec
    else:
        spec = SP.decode_specs(cfg, shape)
        assert spec["token"].shape == (shape.global_batch, 1)
        # cache tree must be constructible for the full seq_len
        cache = build_model(cfg).cache_shapes(shape.global_batch, shape.seq_len)
        leaves = jax.tree_util.tree_leaves(cache)
        assert leaves, "empty cache tree"
        # sliding-window archs bound their attention cache by the window
        if cfg.attn_window is not None:
            for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
                names = [str(getattr(p, "key", "")) for p in path]
                if "k" in names or "c_kv" in names:
                    # length dim is after the stack+batch dims
                    assert cfg.attn_window in leaf.shape or \
                        min(shape.seq_len, cfg.attn_window) in leaf.shape


def test_materialize_and_zeros():
    cfg = get_config("gemma-7b").reduced()
    from repro.configs.base import InputShape
    sh = InputShape("t", 32, 2, "train")
    spec = SP.train_specs(cfg, sh)
    batch = SP.materialize(jax.random.PRNGKey(0), spec)
    assert batch["tokens"].dtype == jnp.int32
    zeros = SP.zeros_like_spec(spec)
    assert float(jnp.sum(jnp.abs(zeros["tokens"]))) == 0


def test_long500k_window_variants():
    """Dense archs get the sliding-window variant at 500k; SSM/hybrid don't
    need it (DESIGN.md §4)."""
    shape = INPUT_SHAPES["long_500k"]
    assert config_for("llama3-405b", shape).attn_window == 4096
    assert config_for("gemma-7b", shape).attn_window == 4096
    assert config_for("mamba2-370m", shape).attn_window is None
    assert config_for("zamba2-1.2b", shape).attn_window == 4096  # shared attn
    # and the variant is NOT applied at other shapes
    assert config_for("llama3-405b", INPUT_SHAPES["train_4k"]).attn_window is None
