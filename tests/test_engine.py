"""Engine-layer tests: the vmapped jitted multi-client path must be
numerically equivalent to the per-client Python loop, record identical
communication, and be the one implementation both the protocol and the
multi-pod schedule train with."""
import jax
import jax.numpy as jnp
import pytest

from repro import engine
from repro.core import ProtocolConfig, SSLConfig, run_one_shot
from repro.core.client import make_client, ssl_task_for
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


@pytest.fixture(scope="module")
def homo_split():
    """Synthetic vertical data with EQUAL per-party feature dims → the
    engine's homogeneous fast path applies."""
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 700)
    return make_vfl_partition(x[:, :22], y, overlap_size=64,
                              feature_sizes=[11, 11], seed=1)


def _clients(key, split, dims):
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in dims]
    return [make_client(jax.random.fold_in(key, i), i, e, split.num_classes,
                        sample_input=split.aligned[i][:2],
                        ssl_cfg=SSLConfig(modality="tabular"),
                        local_data_for_mean=split.unaligned[i])
            for i, e in enumerate(ext)]


def _tasks(key, split, clients):
    tasks = []
    for c, g_dim, x_o, x_u in zip(clients, range(len(clients)),
                                  split.aligned, split.unaligned):
        y_pseudo = jax.random.randint(jax.random.fold_in(key, g_dim),
                                      (x_o.shape[0],), 0, split.num_classes)
        tasks.append(ssl_task_for(c, x_o, y_pseudo, x_u))
    return tasks


HP = engine.SSLHParams(epochs=2, batch_size=32)


def test_vmap_equivalent_to_python_loop(homo_split):
    """The tentpole invariant: vmap-over-clients scan == per-client Python
    loop, at atol 1e-5 on every parameter leaf."""
    key = jax.random.PRNGKey(7)
    clients = _clients(jax.random.PRNGKey(1), homo_split, [0, 1])
    tasks = _tasks(jax.random.PRNGKey(2), homo_split, clients)

    p_vmap, m_vmap, vmapped = engine.train_clients_ssl(key, tasks, HP,
                                                       mode="vmap")
    p_py, m_py, vmapped_py = engine.train_clients_ssl(key, tasks, HP,
                                                      mode="python")
    assert vmapped and not vmapped_py
    for pv, pp in zip(p_vmap, p_py):
        for lv, lp in zip(jax.tree_util.tree_leaves(pv),
                          jax.tree_util.tree_leaves(pp)):
            assert jnp.allclose(lv, lp, atol=1e-5), float(jnp.max(jnp.abs(lv - lp)))
    for mv, mp in zip(m_vmap, m_py):
        assert mv.keys() == mp.keys()
        for name in mv:
            assert abs(mv[name] - mp[name]) < 1e-4, (name, mv[name], mp[name])


def test_auto_dispatch(homo_split, monkeypatch):
    """auto → vmap on homogeneous zoos, Python fallback on heterogeneous.

    Pins the DEFAULT dispatch, so the CI matrix's REPRO_ENGINE_MODE
    override (which deliberately re-steers "auto") is stripped here."""
    monkeypatch.delenv("REPRO_ENGINE_MODE", raising=False)
    clients = _clients(jax.random.PRNGKey(1), homo_split, [0, 1])
    tasks = _tasks(jax.random.PRNGKey(2), homo_split, clients)
    assert engine.tasks_are_homogeneous(tasks)
    _, _, vmapped = engine.train_clients_ssl(jax.random.PRNGKey(3), tasks, HP,
                                             mode="auto")
    assert vmapped

    x, y = make_tabular_credit(jax.random.PRNGKey(0), 700)
    hetero = make_vfl_partition(x, y, overlap_size=64, feature_sizes=[10, 13],
                                seed=1)
    h_clients = _clients(jax.random.PRNGKey(1), hetero, [0, 1])
    h_tasks = _tasks(jax.random.PRNGKey(2), hetero, h_clients)
    assert not engine.tasks_are_homogeneous(h_tasks)
    _, _, vmapped = engine.train_clients_ssl(jax.random.PRNGKey(3), h_tasks,
                                             HP, mode="auto")
    assert not vmapped
    with pytest.raises(ValueError):
        engine.train_clients_ssl(jax.random.PRNGKey(3), h_tasks, HP,
                                 mode="vmap")


def test_vmap_mode_honored_for_single_party(homo_split, monkeypatch):
    """Explicit mode='vmap' must run the fast path even with K=1 (auto may
    still prefer the plain loop there). Default-dispatch test: the CI
    matrix's REPRO_ENGINE_MODE override is stripped."""
    monkeypatch.delenv("REPRO_ENGINE_MODE", raising=False)
    clients = _clients(jax.random.PRNGKey(1), homo_split, [0, 1])[:1]
    tasks = _tasks(jax.random.PRNGKey(2), homo_split, clients)[:1]
    _, _, vmapped = engine.train_clients_ssl(jax.random.PRNGKey(3), tasks, HP,
                                             mode="vmap")
    assert vmapped
    _, _, vmapped = engine.train_clients_ssl(jax.random.PRNGKey(3), tasks, HP,
                                             mode="auto")
    assert not vmapped


def test_homogeneity_checks_forward_fn(homo_split):
    """Same param shapes but a different apply function must NOT be stacked
    under party 0's extractor — shape equality alone is not homogeneity."""
    from dataclasses import replace as dc_replace

    from repro.models import Model, make_mlp_extractor

    clients = _clients(jax.random.PRNGKey(1), homo_split, [0, 1])
    tasks = _tasks(jax.random.PRNGKey(2), homo_split, clients)
    assert engine.tasks_are_homogeneous(tasks)

    base = make_mlp_extractor(rep_dim=8, hidden=(16,))

    def tanh_apply(params, x, train=False):
        del train
        h = jnp.tanh(x @ params["w0"] + params["b0"])
        return h @ params["w1"] + params["b1"]

    odd = Model(init=base.init, apply=tanh_apply, rep_dim=8)
    tasks_odd = [tasks[0], dc_replace(tasks[1], extractor=odd)]
    assert not engine.tasks_are_homogeneous(tasks_odd)


def test_few_shot_with_vmap_mode(homo_split):
    """engine_mode='vmap' must survive the whole few-shot run ON the fast
    path: phase ⑤''s masked fixed-shape sessions stack at any ragged
    per-party gate counts (DESIGN.md §9) — no downgrade, no raise."""
    from repro.core import run_few_shot

    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2
    cfg = ProtocolConfig(client_epochs=2, server_epochs=3, engine_mode="vmap")
    res = run_few_shot(jax.random.PRNGKey(1), homo_split, ext, ssl, cfg)
    assert res.diagnostics["engine_path"] == "vmap"
    assert res.ledger.comm_times() == 5
    assert res.metric > 0.5


def test_protocol_ledger_identical_across_paths(homo_split):
    """run_one_shot through either engine path: identical CommLedger bytes,
    the paper's 3 comm times, and matching metrics."""
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2
    results = {}
    for mode in ("vmap", "python"):
        cfg = ProtocolConfig(client_epochs=2, server_epochs=3,
                             engine_mode=mode)
        results[mode] = run_one_shot(jax.random.PRNGKey(1), homo_split, ext,
                                     ssl, cfg)
        assert results[mode].diagnostics["engine_path"] == mode
    v, p = results["vmap"].ledger, results["python"].ledger
    assert v.total_bytes() == p.total_bytes()
    assert v.comm_times() == p.comm_times() == 3
    assert v.by_tag() == p.by_tag()
    assert abs(results["vmap"].metric - results["python"].metric) < 1e-3


def test_vfl_step_shares_engine_implementation():
    """The multi-pod schedule must train with the engine's step function and
    the real repro.models extractor — no private re-implementation."""
    import inspect

    from repro.launch import vfl_step

    assert vfl_step.make_ssl_step_fn is engine.make_ssl_step_fn
    assert vfl_step.make_ssl_optimizer is engine.make_ssl_optimizer
    assert not hasattr(vfl_step, "_extract")
    src = inspect.getsource(vfl_step)
    assert "make_mlp_extractor" in src
    assert "gradient_pseudo_labels" in src


def test_schedule_shapes():
    sched = engine.build_schedule(jax.random.PRNGKey(0), n_labeled=64,
                                  n_unlabeled=100,
                                  hp=engine.SSLHParams(epochs=3, batch_size=32,
                                                       unlabeled_ratio=2))
    steps = 3 * (64 // 32)
    assert sched.idx_labeled.shape == (steps, 32)
    assert sched.idx_unlabeled.shape == (steps, 64)
    assert sched.step_keys.shape[0] == steps
    assert int(jnp.max(sched.idx_labeled)) < 64
    assert int(jnp.max(sched.idx_unlabeled)) < 100
