"""Masked fixed-shape SSL sessions + the shared session cache (DESIGN.md §9).

* vmap ≡ python parity for *masked* tasks at deliberately ragged per-party
  valid-row counts (the few-shot ⑤' shape problem, isolated);
* ``run_few_shot`` keeps the vmapped engine path end-to-end under
  ``engine_mode="vmap"`` — no downgrade — with byte-identical ledgers
  across modes;
* Eq. 9 gating is deterministic (every sample with p̂ > 0 is kept); the
  legacy Bernoulli subsampling sits behind ``fewshot_stochastic_gate``;
* all-gated pools are represented as zero-valid unlabeled masks (no row in
  both the labeled and unlabeled sets, l_u exactly 0);
* the second seed of a sweep re-serves cached SSL and server-fit sessions
  (recompile-count regression);
* ``ProtocolConfig`` / ``IterativeConfig`` are frozen — no shared mutable
  default config across runner calls.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.core import (IterativeConfig, ProtocolConfig, SSLConfig,
                        run_few_shot)
from repro.core.client import make_client, ssl_task_for
from repro.core.ssl import ssl_loss
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor

HP = engine.SSLHParams(epochs=2, batch_size=32)


@pytest.fixture(scope="module")
def homo_split():
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 700)
    return make_vfl_partition(x[:, :22], y, overlap_size=64,
                              feature_sizes=[11, 11], seed=1)


def _clients(key, split):
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    return [make_client(jax.random.fold_in(key, i), i, e, split.num_classes,
                        sample_input=split.aligned[i][:2],
                        ssl_cfg=SSLConfig(modality="tabular"),
                        local_data_for_mean=split.unaligned[i])
            for i, e in enumerate(ext)]


def _masked_tasks(key, split, clients, valid_counts):
    """Few-shot-⑤'-shaped tasks: labeled = x_o ∘ x_u at full capacity, with
    deliberately ragged per-party gate counts via the validity masks."""
    tasks = []
    for c, n_take, x_o, x_u in zip(clients, valid_counts, split.aligned,
                                   split.unaligned):
        x_lab = jnp.concatenate([x_o, x_u], axis=0)
        y_lab = jax.random.randint(jax.random.fold_in(key, c.index),
                                   (x_lab.shape[0],), 0, split.num_classes)
        take = jnp.zeros(x_u.shape[0], jnp.float32).at[:n_take].set(1.0)
        lab_mask = jnp.concatenate([jnp.ones(x_o.shape[0], jnp.float32), take])
        tasks.append(ssl_task_for(c, x_lab, y_lab, x_u,
                                  labeled_mask=lab_mask,
                                  unlabeled_mask=1.0 - take))
    return tasks


def test_masked_tasks_are_homogeneous_at_ragged_counts(homo_split):
    clients = _clients(jax.random.PRNGKey(1), homo_split)
    tasks = _masked_tasks(jax.random.PRNGKey(2), homo_split, clients, [7, 201])
    assert engine.tasks_are_homogeneous(tasks)
    # mask presence must still be consistent across parties
    bare = dataclasses.replace(tasks[1], labeled_mask=None,
                               unlabeled_mask=None)
    assert not engine.tasks_are_homogeneous([tasks[0], bare])


def test_masked_vmap_equivalent_to_python_loop(homo_split):
    """The tentpole invariant at ragged gate counts: masked fast path ==
    per-client Python fallback at atol 1e-5 on every parameter leaf."""
    clients = _clients(jax.random.PRNGKey(1), homo_split)
    tasks = _masked_tasks(jax.random.PRNGKey(2), homo_split, clients, [3, 170])
    key = jax.random.PRNGKey(7)
    p_vmap, m_vmap, vmapped = engine.train_clients_ssl(key, tasks, HP,
                                                       mode="vmap")
    p_py, m_py, vmapped_py = engine.train_clients_ssl(key, tasks, HP,
                                                      mode="python")
    assert vmapped and not vmapped_py
    for pv, pp in zip(p_vmap, p_py):
        for lv, lp in zip(jax.tree_util.tree_leaves(pv),
                          jax.tree_util.tree_leaves(pp)):
            assert jnp.allclose(lv, lp, atol=1e-5), \
                float(jnp.max(jnp.abs(lv - lp)))
    for mv, mp in zip(m_vmap, m_py):
        assert mv.keys() == mp.keys()
        for name in mv:
            assert abs(mv[name] - mp[name]) < 1e-4, (name, mv[name], mp[name])


def test_masked_rows_contribute_zero_loss(homo_split):
    """An all-ones mask reproduces the unmasked loss; padded rows with junk
    data change nothing; a zero-valid unlabeled batch has l_u == 0 exactly
    (the empty-pool representation — no row in both sets, no [:1] leak)."""
    clients = _clients(jax.random.PRNGKey(1), homo_split)
    c = clients[0]
    x_o, x_u = homo_split.aligned[0], homo_split.unaligned[0]
    xb_l, xb_u = x_o[:16], x_u[:32]
    yb = jnp.zeros(16, jnp.int32)
    key = jax.random.PRNGKey(3)

    def logits_fn(p, x):
        return c.head.apply(p.head, c.extractor.apply(p.extractor, x))

    cfg = c.ssl_cfg
    base, _ = ssl_loss(logits_fn, c.params, key, xb_l, yb, xb_u, cfg,
                       c.feature_mean)
    ones, _ = ssl_loss(logits_fn, c.params, key, xb_l, yb, xb_u, cfg,
                       c.feature_mean,
                       labeled_mask=jnp.ones(16), unlabeled_mask=jnp.ones(32))
    assert jnp.allclose(base, ones, atol=1e-6)

    # corrupt the masked-out half of the labeled batch: loss is unchanged
    half = jnp.concatenate([jnp.ones(8), jnp.zeros(8)])
    ref, _ = ssl_loss(logits_fn, c.params, key, xb_l, yb, xb_u, cfg,
                      c.feature_mean, labeled_mask=half)
    junk = xb_l.at[8:].set(1e3)
    got, _ = ssl_loss(logits_fn, c.params, key, junk, yb, xb_u, cfg,
                      c.feature_mean, labeled_mask=half)
    assert jnp.allclose(ref, got, atol=1e-6)

    # zero-valid unlabeled batch == empty pool: l_u exactly 0
    _, metrics = ssl_loss(logits_fn, c.params, key, xb_l, yb, xb_u, cfg,
                          c.feature_mean, unlabeled_mask=jnp.zeros(32))
    assert float(metrics["l_u"]) == 0.0
    assert float(metrics["pseudo_mask_rate"]) == 0.0


def _fast(**kw):
    return ProtocolConfig(client_epochs=2, server_epochs=3, **kw)


def test_few_shot_stays_on_vmap_path_with_ragged_gates(homo_split):
    """engine_mode='vmap' survives the whole few-shot run: phase ⑤''s masked
    sessions stack at any per-party gate counts — no downgrade, and the
    ledger is byte-identical to the python path's."""
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2
    results = {}
    for mode in ("vmap", "python"):
        res = run_few_shot(jax.random.PRNGKey(1), homo_split, ext, ssl,
                           _fast(engine_mode=mode))
        assert res.diagnostics["engine_path"] == mode
        assert res.ledger.comm_times() == 5
        results[mode] = res
    # ragged gates actually exercised (else the test proves nothing)
    takes = results["vmap"].diagnostics["fewshot_take_rate"]
    assert takes[0] != takes[1]
    v, p = results["vmap"].ledger, results["python"].ledger
    assert v.total_bytes() == p.total_bytes()
    assert v.by_tag() == p.by_tag()
    assert abs(results["vmap"].metric - results["python"].metric) < 1e-3


def test_eq9_gate_is_deterministic_by_default(homo_split):
    """The paper keeps ALL samples passing the Eq. 9 gate: the take rate
    must equal the gate rate (p̂ > 0), and two runs with different PRNG
    keys but identical upstream state agree. The Bernoulli subsampling
    only engages behind fewshot_stochastic_gate."""
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2
    res = run_few_shot(jax.random.PRNGKey(1), homo_split, ext, ssl, _fast())
    assert res.diagnostics["fewshot_take_rate"] == \
        res.diagnostics["fewshot_gate_rate"]
    res_s = run_few_shot(jax.random.PRNGKey(1), homo_split, ext, ssl,
                         _fast(fewshot_stochastic_gate=True))
    # Bernoulli(p̂ ≤ 1) keeps at most the gated samples, a.s. fewer
    for t_s, t_d in zip(res_s.diagnostics["fewshot_take_rate"],
                        res.diagnostics["fewshot_take_rate"]):
        assert t_s <= t_d


def test_sweep_reuses_cached_ssl_and_server_fit_sessions(homo_split):
    """Recompile-count regression: the second seed of a sweep must add ZERO
    fresh compiles — both the SSL sessions and every server classifier fit
    re-serve the cached compiled programs."""
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2
    engine.clear_session_cache()
    run_few_shot(jax.random.PRNGKey(0), homo_split, ext, ssl, _fast())
    first = engine.session_cache_stats_by_domain()
    assert first["server_fit"]["misses"] == 1     # K aux + joint + refits: 1 arch
    assert first["server_fit"]["hits"] >= 3
    assert first["ssl"]["misses"] >= 1
    # fresh-but-equivalent extractors (same factory args) on another seed
    ext2 = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    run_few_shot(jax.random.PRNGKey(1), homo_split, ext2, ssl, _fast())
    second = engine.session_cache_stats_by_domain()
    assert second["server_fit"]["misses"] == first["server_fit"]["misses"]
    assert second["ssl"]["misses"] == first["ssl"]["misses"]
    assert second["ssl"]["hits"] > first["ssl"]["hits"]


def test_configs_are_frozen_and_not_shared():
    with pytest.raises(dataclasses.FrozenInstanceError):
        ProtocolConfig().fewshot_threshold = 0.5
    with pytest.raises(dataclasses.FrozenInstanceError):
        IterativeConfig().iterations = 7
    # replace() is the supported mutation idiom
    assert dataclasses.replace(ProtocolConfig(),
                               fewshot_threshold=0.5).fewshot_threshold == 0.5


def test_all_gated_pool_trains_without_leak(homo_split):
    """When every unaligned sample passes the gate the unlabeled mask is
    all-zero: the session still runs (l_u == 0) instead of recycling
    x_u[:1] into both sets."""
    clients = _clients(jax.random.PRNGKey(1), homo_split)
    n_u = homo_split.unaligned[0].shape[0]
    tasks = _masked_tasks(jax.random.PRNGKey(2), homo_split, clients,
                          [n_u, n_u])
    for t in tasks:
        assert float(jnp.sum(t.unlabeled_mask)) == 0.0
    params, metrics, vmapped = engine.train_clients_ssl(
        jax.random.PRNGKey(3), tasks, HP, mode="vmap")
    assert vmapped
    for m in metrics:
        assert m["l_u"] == 0.0
        assert np.isfinite(m["loss"])
