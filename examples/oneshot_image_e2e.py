"""End-to-end driver: the paper's image experiment (halved images, CNN
extractors, FixMatch SSL) — one-shot vs few-shot vs vanilla VFL, with the
full communication ledger. This is the training-kind e2e deliverable: the
one-shot session trains two ~1M-param CNN extractors for several hundred
effective local steps.

  PYTHONPATH=src python examples/oneshot_image_e2e.py [--epochs 4]
"""
import argparse
import time

import jax

from repro.core import (IterativeConfig, ProtocolConfig, SSLConfig,
                        run_few_shot, run_one_shot, run_vanilla)
from repro.data import make_image_classification, make_vfl_partition
from repro.models import make_cnn_extractor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--epochs", type=int, default=60,
                    help="local SSL epochs (the overlap set is tiny — FixMatch "
                         "needs many passes; see EXPERIMENTS §Paper-claims)")
    ap.add_argument("--samples", type=int, default=2400)
    ap.add_argument("--overlap", type=int, default=64)
    ap.add_argument("--classes", type=int, default=6)
    ap.add_argument("--iters", type=int, default=400)
    args = ap.parse_args()

    x, y = make_image_classification(jax.random.PRNGKey(0), args.samples,
                                     num_classes=args.classes, image_size=16)
    split = make_vfl_partition(x, y, overlap_size=args.overlap, seed=1,
                               num_classes=args.classes)
    mk = lambda: [make_cnn_extractor(rep_dim=64, widths=(8, 16),
                                     blocks_per_stage=1) for _ in range(2)]
    ssl = [SSLConfig(modality="image", max_shift=2, cutout_size=4,
                     confidence_threshold=0.6)] * 2
    pcfg = ProtocolConfig(client_epochs=args.epochs,
                          server_epochs=min(3 * args.epochs, 60),
                          client_lr=0.02)

    for name, fn in {
        "one-shot": lambda: run_one_shot(jax.random.PRNGKey(2), split, mk(), ssl, pcfg),
        "few-shot": lambda: run_few_shot(jax.random.PRNGKey(2), split, mk(), ssl, pcfg),
        "vanilla": lambda: run_vanilla(jax.random.PRNGKey(2), split, mk(), ssl,
                                       IterativeConfig(iterations=args.iters)),
    }.items():
        t0 = time.time()
        res = fn()
        print(f"{name:9s} acc={res.metric:.4f} "
              f"comm_times={res.ledger.comm_times():6d} "
              f"comm={res.ledger.total_megabytes():9.2f}MB "
              f"wall={time.time() - t0:6.1f}s")
        if name == "one-shot":
            print(f"          kmeans purity per client: "
                  f"{[f'{p:.3f}' for p in res.diagnostics['kmeans_purity']]}")


if __name__ == "__main__":
    main()
