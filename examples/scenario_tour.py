"""Tour the scenario registry, then race one-shot VFL against the iterative
baseline on a chosen scenario.

    PYTHONPATH=src python examples/scenario_tour.py                  # list
    PYTHONPATH=src python examples/scenario_tour.py hard/overlap-32  # race

The race prints the paper's three columns (metric, comm times, comm MB) for
both methods — on the ``hard/*`` scenarios one-shot wins both axes at once.
"""
import argparse
import sys

import jax

from repro import scenarios
from repro.core import IterativeConfig, ProtocolConfig, run_one_shot, run_vanilla


def list_registry() -> None:
    print(f"{len(scenarios.names())} registered scenarios:\n")
    for name in scenarios.names():
        s = scenarios.get(name)
        tags = ",".join(s.tags)
        print(f"  {name:22s} K={s.num_parties} N_o={s.overlap:<5d} "
              f"{s.modality:8s} [{tags}]  {s.description}")


def race(name: str, seed: int, smoke: bool) -> None:
    bundle = scenarios.build(name, seed=seed, smoke=smoke)
    spec = bundle.spec
    print(f"scenario {spec.name}: K={spec.num_parties}, N_o={spec.overlap}, "
          f"pools={[int(u.shape[0]) for u in bundle.split.unaligned]}")
    one = run_one_shot(
        jax.random.PRNGKey(seed), bundle.split, bundle.extractors,
        bundle.ssl_cfgs,
        ProtocolConfig(client_epochs=spec.budget("client_epochs", 8),
                       server_epochs=spec.budget("server_epochs", 30)))
    van = run_vanilla(
        jax.random.PRNGKey(seed), bundle.split, bundle.extractors,
        bundle.ssl_cfgs,
        IterativeConfig(iterations=spec.budget("iterations", 300)))
    for label, res in (("one-shot", one), ("iterative", van)):
        row = res.summary_row()
        print(f"  {label:10s} {row['metric_name']}={row['metric']:.4f} "
              f"times={row['comm_times']:<6d} "
              f"mb={row['comm_bytes'] / 2**20:8.3f}")
    ratio = van.ledger.total_bytes() / max(one.ledger.total_bytes(), 1)
    print(f"  one-shot moves {ratio:.0f}x fewer bytes")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("scenario", nargs="?", default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full", action="store_true",
                    help="full-size build (default: smoke sizes)")
    args = ap.parse_args()
    if args.scenario is None:
        list_registry()
        return
    race(args.scenario, args.seed, smoke=not args.full)


if __name__ == "__main__":
    sys.exit(main())
