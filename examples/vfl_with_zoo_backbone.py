"""One-shot VFL where the party extractors are assigned-architecture
backbones (reduced configs): party A runs a Gemma-style dense transformer,
party B a Mamba2 SSM, each over its own token-range slice of the sequence —
the DESIGN.md §4 "technique × architecture" integration, end to end.

  PYTHONPATH=src python examples/vfl_with_zoo_backbone.py
"""
import jax

from repro.configs import get_config
from repro.core import ProtocolConfig, SSLConfig, run_one_shot
from repro.data.synthetic import make_sequence_classification
from repro.data.vertical import VerticalSplit
from repro.models.zoo_extractor import make_zoo_extractor

import numpy as np


def main() -> None:
    key = jax.random.PRNGKey(0)
    x, y = make_sequence_classification(key, 1200, seq_len=32, vocab_size=64,
                                        num_classes=4)
    # vertical split: token range [0:16) → party A, [16:32) → party B
    n = x.shape[0]
    rng = np.random.RandomState(0)
    perm = rng.permutation(n)
    test, over, rest = perm[:200], perm[200:328], perm[328:]
    halves = lambda idx: [x[idx, :16], x[idx, 16:]]
    pool = np.array_split(rest, 2)
    split = VerticalSplit(
        aligned=halves(over), labels=y[over],
        unaligned=[x[pool[0], :16], x[pool[1], 16:]],
        test_aligned=halves(test), test_labels=y[test], num_classes=4)

    cfg_a = get_config("gemma-7b").reduced()
    cfg_b = get_config("mamba2-370m").reduced()
    import dataclasses
    cfg_a = dataclasses.replace(cfg_a, vocab_size=64, num_layers=2)
    cfg_b = dataclasses.replace(cfg_b, vocab_size=64, num_layers=2)
    extractors = [make_zoo_extractor(cfg_a, rep_dim=32),
                  make_zoo_extractor(cfg_b, rep_dim=32)]
    ssl = [SSLConfig(modality="token", mask_ratio=0.15)] * 2

    res = run_one_shot(jax.random.PRNGKey(1), split, extractors, ssl,
                       ProtocolConfig(client_epochs=6, server_epochs=20,
                                      client_lr=0.02))
    print(f"backbones: {cfg_a.name} (dense) + {cfg_b.name} (SSM)")
    print(f"accuracy  : {res.metric:.4f}  (chance 0.25)")
    print(f"purity    : {[round(p, 3) for p in res.diagnostics['kmeans_purity']]}")
    print(f"comm      : {res.ledger.comm_times()} times, "
          f"{res.ledger.total_megabytes():.3f} MB")


if __name__ == "__main__":
    main()
