"""Train → save → load → query: the full VFL deployment loop (DESIGN.md §13).

One-shot VFL trains a joint model in 3 communications per client; this demo
exports it as a typed, versioned artifact, reloads it as a deployment would,
and serves queries through the fused batched forward — including a
partial-party query answered via Eq. 10 representation estimation.

  PYTHONPATH=src python examples/serve_demo.py
"""
import tempfile

import jax
import jax.numpy as jnp

from repro import scenarios
from repro.checkpoint import load_artifact, save_artifact
from repro.core import ProtocolConfig, run_one_shot
from repro.launch.vfl_serve import ServingEngine, serve_traffic, \
    synthetic_requests


def main() -> None:
    # 1. TRAIN: one scenario point from the registry, one-shot protocol
    spec = scenarios.get("hard/overlap-32")
    bundle = scenarios.build(spec, seed=0, smoke=True)
    cfg = ProtocolConfig(client_epochs=5, server_epochs=15)
    result = run_one_shot(jax.random.PRNGKey(0), bundle.split,
                          bundle.extractors, bundle.ssl_cfgs, cfg)
    print(f"trained {spec.name}: {result.metric_name}={result.metric:.4f} "
          f"({result.ledger.comm_times()} comm times/client)")

    # 2. SAVE: every VFLResult exports as a deployment artifact
    art_dir = tempfile.mkdtemp(prefix="vfl-artifact-")
    art = result.to_artifact(spec, cfg=cfg, split=bundle.split)
    path = save_artifact(art_dir, art)
    print(f"saved artifact -> {path}")

    # 3. LOAD: a fresh process would start here
    art = load_artifact(art_dir)
    print(f"loaded: K={art.num_parties} parties, "
          f"homogeneous={art.parties_are_homogeneous}, "
          f"version={art.version}")

    # 4. QUERY: the fused forward behind the fixed-shape masked batcher
    engine = ServingEngine(art, capacity=32)
    xs = [x[:10] for x in bundle.split.aligned]     # 10 full-party queries
    preds = engine.predict(xs)
    print(f"batched predictions : {preds.tolist()}")

    # parity with the artifact's unbatched reference oracle
    ref = jnp.argmax(art.predict_logits(xs), axis=-1)
    assert (preds == ref).all()

    # a party querying WITHOUT the other parties' features: Eq. 10
    # estimation over the artifact's stored overlap representations
    partial = engine.predict_logits_partial(bundle.split.aligned[0][:4], 0)
    print(f"partial-party logits: {jnp.argmax(partial, -1).tolist()} "
          f"(party 0 alone, others estimated)")

    # 5. TRAFFIC: continuous batched serving with latency accounting
    reqs = synthetic_requests(art, num_requests=16, batch_size=32)
    _, rec = serve_traffic(engine, reqs)
    s = rec.summary()
    print(f"served {s['rows']} rows: p50={s['p50_ms']:.2f}ms "
          f"p99={s['p99_ms']:.2f}ms {s['rows_per_s']:.0f} rows/s")


if __name__ == "__main__":
    main()
