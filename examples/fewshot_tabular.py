"""Few-shot VFL walkthrough on tabular data: shows the SDPA representation
estimation (Eq. 10), the Eq. 8-9 gating, and the labeled-set expansion —
with the gate rate and the 5-round ledger printed at each stage.

  PYTHONPATH=src python examples/fewshot_tabular.py
"""
import jax

from repro.core import ProtocolConfig, SSLConfig, run_few_shot, run_one_shot
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


def main() -> None:
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 4000)
    # a deliberately tiny overlap — the regime few-shot targets
    split = make_vfl_partition(x, y, overlap_size=64,
                               feature_sizes=[10, 13], seed=1)
    mk = lambda: [make_mlp_extractor(rep_dim=32, hidden=(64,)) for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2
    cfg = ProtocolConfig(client_epochs=5, server_epochs=15,
                         fewshot_threshold=0.85, use_kernels=False)

    one = run_one_shot(jax.random.PRNGKey(1), split, mk(), ssl, cfg)
    few = run_few_shot(jax.random.PRNGKey(1), split, mk(), ssl, cfg)

    print(f"overlap=64  one-shot AUC={one.metric:.4f} "
          f"({one.ledger.comm_times()} comm times)")
    print(f"overlap=64  few-shot AUC={few.metric:.4f} "
          f"({few.ledger.comm_times()} comm times)")
    print(f"pseudo-label gate rate per client: "
          f"{[f'{g:.2%}' for g in few.diagnostics['fewshot_gate_rate']]}")
    print()
    print(few.ledger.summary())


if __name__ == "__main__":
    main()
