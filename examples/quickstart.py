"""Quickstart: one-shot VFL on a synthetic credit-default task in ~a minute.

Two parties hold 10/13 features of the same users; the server holds labels
for a 200-user overlap. One-shot VFL trains both extractors with exactly
3 communications per client.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax

from repro.core import ProtocolConfig, SSLConfig, run_one_shot
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


def main() -> None:
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 3000)
    split = make_vfl_partition(x, y, overlap_size=200,
                               feature_sizes=[10, 13], seed=1)
    extractors = [make_mlp_extractor(rep_dim=32, hidden=(64,))
                  for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2

    result = run_one_shot(jax.random.PRNGKey(1), split, extractors, ssl,
                          ProtocolConfig(client_epochs=5, server_epochs=15))

    print(f"test AUC            : {result.metric:.4f}")
    print(f"k-means purity      : {result.diagnostics['kmeans_purity']}")
    print(f"comm times/client   : {result.ledger.comm_times()}   (paper: 3)")
    print(result.ledger.summary())


if __name__ == "__main__":
    main()
