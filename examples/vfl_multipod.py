"""Multi-pod collective schedule demo (DESIGN.md §3): compile vanilla-VFL
and one-shot-VFL as programs on the 2×16×16 production mesh and count the
pod-crossing collectives in the partitioned HLO.

This is the paper's communication claim restated at the systems level: a
training session of N iterations crosses the slow inter-pod links 2N times
under vanilla VFL, and exactly 3 times under one-shot VFL.

  PYTHONPATH=src python examples/vfl_multipod.py
"""
import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import jax
import jax.numpy as jnp

from repro.launch.mesh import make_production_mesh
from repro.launch.vfl_step import (count_pod_collectives, extractor_shapes,
                                   make_oneshot_vfl_session,
                                   make_vanilla_vfl_step)


def main() -> None:
    mesh = make_production_mesh(multi_pod=True)
    F, H, R, C, B = 64, 128, 32, 10, 256
    params = extractor_shapes(F, H, R, 2)
    x = jax.ShapeDtypeStruct((2, B, F), jnp.float32)
    xu = jax.ShapeDtypeStruct((2, B * 4, F), jnp.float32)
    y = jax.ShapeDtypeStruct((B,), jnp.int32)
    wh = jax.ShapeDtypeStruct((2 * R, C), jnp.float32)

    with mesh:
        vanilla = jax.jit(make_vanilla_vfl_step(mesh, F, H, R, C)) \
            .lower(params, x, y, wh).compile()
        oneshot = jax.jit(make_oneshot_vfl_session(mesh, F, H, R, C,
                                                   local_steps=100)) \
            .lower(params, x, xu, y, wh).compile()

    cv = count_pod_collectives(vanilla.as_text())
    co = count_pod_collectives(oneshot.as_text())
    steps = 1000
    print(f"mesh {mesh.devices.shape} axes {mesh.axis_names}")
    print(f"vanilla VFL step    : {cv['pod_crossing']} pod-crossing "
          f"collectives per iteration")
    print(f"one-shot VFL session: {co['pod_crossing']} pod-crossing "
          f"collectives TOTAL (100 local steps inside)")
    print(f"→ a {steps}-iteration session crosses pods "
          f"{cv['pod_crossing'] * steps}× (vanilla) vs {co['pod_crossing']}× "
          f"(one-shot): {cv['pod_crossing'] * steps // co['pod_crossing']}× fewer")


if __name__ == "__main__":
    main()
