"""Train any assigned architecture (reduced) on synthetic tokens — the model
zoo's runnable path for all 10 families:

  PYTHONPATH=src python examples/zoo_train_lm.py --arch deepseek-v2-236b
  PYTHONPATH=src python examples/zoo_train_lm.py --arch mamba2-370m --steps 50
"""
import argparse

from repro.launch import train as train_mod


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="mamba2-370m")
    ap.add_argument("--steps", type=int, default=30)
    args = ap.parse_args()
    import sys
    sys.argv = ["train", "--arch", args.arch, "--reduce",
                "--steps", str(args.steps), "--batch", "4", "--seq", "64"]
    train_mod.main()


if __name__ == "__main__":
    main()
