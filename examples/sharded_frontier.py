"""Run a scenario-folded sweep sharded over a device mesh (DESIGN.md §14).

    PYTHONPATH=src python examples/sharded_frontier.py --devices 4

On a CPU-only machine the device pool is forced via
``launch.mesh.forced_host_devices`` — which is why it is the FIRST thing
this script does, before anything touches the jax backend. The sweep runs
the equal-shape ``hard/overlap-{32,64}-eq`` pair (one fixed padded shape,
so both scenarios stack) × 2 seeds through ``run_scenarios_seeds`` twice
— single-device, then sharded — and prints the metric parity plus each
row's (seed_fold, scenario_fold, device_fold) triple.
"""
import argparse
import sys

from repro.launch.mesh import forced_host_devices


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--devices", type=int, default=4)
    ap.add_argument("--seeds", type=int, default=2)
    args = ap.parse_args()

    forced_host_devices(args.devices)   # BEFORE jax backend init

    import jax

    from repro import scenarios
    from repro.core import ProtocolConfig, run_one_shot
    from repro.core.protocol import run_scenarios_seeds

    print(f"visible devices: {jax.device_count()}")
    names = ["hard/overlap-32-eq", "hard/overlap-64-eq"]
    seeds = list(range(args.seeds))
    bundles = [[scenarios.build(n, seed=s, smoke=True) for s in seeds]
               for n in names]
    grid_args = (
        [[jax.random.PRNGKey(s) for s in seeds] for _ in names],
        [[b.split for b in bs] for bs in bundles],
        [[b.extractors for b in bs] for bs in bundles],
        [[b.ssl_cfgs for b in bs] for bs in bundles],
    )

    cfg = ProtocolConfig(client_epochs=4, server_epochs=10)
    single = run_scenarios_seeds(run_one_shot, *grid_args, cfg)
    import dataclasses
    sharded = run_scenarios_seeds(
        run_one_shot, *grid_args,
        dataclasses.replace(cfg, mesh=args.devices))

    for name, scen_single, scen_sharded in zip(names, single, sharded):
        for s, (a, b) in enumerate(zip(scen_single, scen_sharded)):
            d = b.diagnostics
            print(f"  {name} seed {s}: metric {a.metric:.4f} -> {b.metric:.4f} "
                  f"(|delta| {abs(a.metric - b.metric):.2e})  folds "
                  f"S={d['seed_fold']} C={d['scenario_fold']} "
                  f"D={d['device_fold']}")


if __name__ == "__main__":
    sys.exit(main())
