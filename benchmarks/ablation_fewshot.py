"""Ablation (beyond the paper's tables): few-shot confidence threshold t
(Eq. 9) — gate rate vs utility, plus the SDPA-vs-oracle estimation quality.

The paper fixes t implicitly; this sweep shows the trade-off the server
operator controls: low t admits noisy pseudo-labels, high t gates everything
off and few-shot degenerates to one-shot.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import ProtocolConfig, SSLConfig, run_few_shot
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    args, _ = ap.parse_known_args()

    thresholds = [0.6, 0.9] if args.fast else [0.5, 0.7, 0.85, 0.95, 0.99]
    x, y = make_tabular_credit(jax.random.PRNGKey(0), 2500)
    split = make_vfl_partition(x, y, overlap_size=64, feature_sizes=[10, 13],
                               seed=1)
    ssl = [SSLConfig(modality="tabular")] * 2
    print("name,us_per_call,derived")
    for t in thresholds:
        ext = [make_mlp_extractor(rep_dim=32, hidden=(64,)) for _ in range(2)]
        cfg = ProtocolConfig(client_epochs=3, server_epochs=10,
                             fewshot_threshold=t)
        t0 = time.time()
        res = run_few_shot(jax.random.PRNGKey(1), split, ext, ssl, cfg)
        gates = res.diagnostics["fewshot_gate_rate"]
        print(f"ablation/fewshot_threshold/{t},{(time.time() - t0) * 1e6:.0f},"
              f"auc={res.metric:.4f};gate_rate={sum(gates) / len(gates):.3f}")


if __name__ == "__main__":
    main()
