"""The serving benchmark: latency/throughput + parity gates on a deployed
artifact.

Loads (or trains and exports) a ``TrainedVFLModel`` and drives it through
``repro.launch.vfl_serve`` at batch 1 / 64 / 1024, reporting per-batch-size
p50/p99 latency and throughput as typed serving rows (``repro.core.rows``
— the SAME row schema the frontier gate consumes). Three contracts are
machine-checked against ``serving_baseline.json``:

* PARITY — batched fused predictions match the artifact's unbatched
  reference forward (``TrainedVFLModel.predict_logits``) at 1e-5 per
  batch size;
* RECOMPILE — the fused forward adds ZERO fresh ``"serving"``-domain
  session-cache misses after the first batch shape (capacities change,
  the cached program does not: its key carries no batch width);
* LATENCY — p50 must stay under the baseline's per-batch-size ceiling
  and throughput above its floor, where the baseline pins one (ceilings
  are optional — ``null`` skips, for CI hosts with noisy clocks).

CI wiring (.github/workflows/ci.yml, job ``bench-smoke``)::

    python -m benchmarks.serving --train --smoke --check-gate \
        --save-artifact artifact-smoke --out BENCH_serving.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro import scenarios
from repro.core import ProtocolConfig
from repro.core import rows as result_rows
from repro.core.protocol import run_one_shot
from repro.checkpoint import load_artifact, save_artifact
from repro.engine import session_cache_stats
from repro.launch import vfl_serve
from repro.launch.vfl_serve import ServingEngine

BASELINE_PATH = os.path.join(os.path.dirname(__file__),
                             "serving_baseline.json")

BATCH_SIZES = (1, 64, 1024)
PARITY_ATOL = 1e-5
TRAIN_SCENARIO = "hard/overlap-32"


def train_artifact(scenario: str = TRAIN_SCENARIO, seed: int = 0,
                   smoke: bool = True):
    """One-shot-train one scenario seed and export it as the deployment
    artifact the bench serves (what ``--train`` runs)."""
    spec = scenarios.get(scenario)
    bundle = scenarios.build(spec, seed=seed, smoke=smoke)
    cfg = ProtocolConfig(
        client_epochs=spec.budget("client_epochs", 8),
        server_epochs=spec.budget("server_epochs", 30),
    )
    res = run_one_shot(jax.random.PRNGKey(seed), bundle.split,
                       bundle.extractors, bundle.ssl_cfgs, cfg)
    return res.to_artifact(spec, cfg=cfg, split=bundle.split)


def _max_abs_diff(a: jnp.ndarray, b: jnp.ndarray) -> float:
    return float(jnp.max(jnp.abs(a - b)))


def bench_artifact(art, batch_sizes=BATCH_SIZES, requests: int = 8,
                   seed: int = 0) -> list:
    """Serve ``requests`` synthetic batches at every batch size; one typed
    serving row per size carrying the latency summary, the parity error
    against the unbatched reference, and the fresh serving-domain session
    builds the size triggered (0 for every size after the first)."""
    rows = []
    for i, bs in enumerate(batch_sizes):
        engine = ServingEngine(art, capacity=bs)
        reqs = vfl_serve.synthetic_requests(art, requests, bs,
                                            seed=seed + i)
        misses0 = session_cache_stats("serving")["misses"]
        outs, rec = vfl_serve.serve_traffic(engine, reqs)
        fresh = session_cache_stats("serving")["misses"] - misses0
        # parity: the fused masked-batched forward vs the per-request
        # unbatched reference oracle, on the first request
        ref = art.predict_logits(list(reqs[0]))
        parity = _max_abs_diff(outs[0], ref)
        s = rec.summary()
        row = result_rows.serving_row(
            "p50_ms", s["p50_ms"],
            scenario=art.scenario,
            batch=bs,
            capacity=engine.capacity,
            requests=len(reqs),
            p99_ms=s["p99_ms"],
            mean_ms=s["mean_ms"],
            rows_per_s=s["rows_per_s"],
            parity_max_abs=parity,
            cache_misses=fresh,
            first_shape=(i == 0),
            homogeneous=art.parties_are_homogeneous,
            num_parties=art.num_parties,
        )
        rows.append(row)
        print(f"{art.scenario:>18s} serve b={bs:<5d} "
              f"p50={s['p50_ms']:8.2f}ms p99={s['p99_ms']:8.2f}ms "
              f"{s['rows_per_s']:10.0f} rows/s "
              f"parity={parity:.2e} fresh_builds={fresh}", flush=True)
    return rows


def check_serving_gate(rows, baseline_path: str = BASELINE_PATH) -> list:
    """The serving regression gate; returns violation strings. Consumes
    the same typed row shape as the frontier's ``check_gate``."""
    problems = []
    with open(baseline_path) as fh:
        baseline = json.load(fh)
    atol = baseline.get("parity_atol", PARITY_ATOL)
    ceilings = baseline.get("max_p50_ms", {})
    floors = baseline.get("min_rows_per_s", {})
    serving = [r for r in rows if r.get("kind") == "serving"]
    if not serving:
        return ["no serving rows to gate"]
    for r in serving:
        bs = str(r["batch"])
        if r["parity_max_abs"] > atol:
            problems.append(
                f"batch {bs}: batched-vs-unbatched parity "
                f"{r['parity_max_abs']:.2e} > {atol:.0e}")
        if not r.get("first_shape") and r["cache_misses"] != 0:
            problems.append(
                f"batch {bs}: {r['cache_misses']} fresh serving-session "
                f"builds after the first batch shape — the fused forward "
                f"must re-serve ONE cached program at every capacity")
        ceiling = ceilings.get(bs)
        if ceiling is not None and r["metric"] > ceiling:
            problems.append(
                f"batch {bs}: p50 {r['metric']:.2f}ms > baseline ceiling "
                f"{ceiling:.2f}ms")
        floor = floors.get(bs)
        if floor is not None and r["rows_per_s"] < floor:
            problems.append(
                f"batch {bs}: throughput {r['rows_per_s']:.0f} rows/s < "
                f"baseline floor {floor:.0f}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    src = ap.add_mutually_exclusive_group(required=True)
    src.add_argument("--artifact", help="serve an existing artifact dir")
    src.add_argument("--train", action="store_true",
                     help=f"train {TRAIN_SCENARIO} (one seed) and serve it")
    ap.add_argument("--smoke", action="store_true",
                    help="train at smoke sizes (CI tier)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch-sizes", type=int, nargs="+",
                    default=list(BATCH_SIZES))
    ap.add_argument("--requests", type=int, default=8,
                    help="timed requests per batch size")
    ap.add_argument("--save-artifact", default=None,
                    help="export the trained artifact here (with --train)")
    ap.add_argument("--out", default="BENCH_serving.json")
    ap.add_argument("--check-gate", action="store_true")
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args(argv)

    t0 = time.time()
    if args.train:
        art = train_artifact(seed=args.seed, smoke=args.smoke)
        print(f"trained {art.scenario}: {art.metric_name}={art.metric:.4f} "
              f"({time.time() - t0:.0f}s)", flush=True)
        if args.save_artifact:
            path = save_artifact(args.save_artifact, art)
            print(f"saved artifact -> {path}")
            # serve what a deployment would: the RELOADED artifact
            art = load_artifact(args.save_artifact)
    else:
        art = load_artifact(args.artifact)

    rows = bench_artifact(art, batch_sizes=tuple(args.batch_sizes),
                          requests=args.requests, seed=args.seed)
    blob = {
        "scenario": art.scenario,
        "seed": args.seed,
        "batch_sizes": list(args.batch_sizes),
        "wall_s": round(time.time() - t0, 2),
        "rows": rows,
    }
    with open(args.out, "w") as fh:
        json.dump(blob, fh, indent=2)
    print(f"wrote {args.out}: {len(rows)} rows in {blob['wall_s']:.0f}s")

    if args.check_gate:
        problems = check_serving_gate(rows, args.baseline)
        if problems:
            for p in problems:
                print(f"SERVING GATE VIOLATION: {p}", file=sys.stderr)
            return 1
        print("serving gate: parity at 1e-5, one cached fused program "
              "across batch shapes, latency within baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
