"""Communication accounting at the PAPER's exact scale (Tab. 1 columns).

Pure ledger arithmetic — no training — cross-checking the implementation's
accounting against the paper's reported numbers: one-shot = 3 comm times and
0.79–6.3 MB; vanilla = 2 comm times/iter and 262–2094 MB; ratio ≥ 330×.

One trained cross-check rides along: a tiny one-shot session is run through
BOTH engine execution paths (vmap fast path / per-client Python loop) and
the ledgers must record byte-identical traffic — the engine refactor cannot
change the communication story.
"""
from __future__ import annotations

from repro.core.comm import CommLedger

REP_DIM = 128      # WideResNet20 feature dim at the paper's setting
BATCH = 32
CLASSES = 10


def vanilla_ledger(iterations: int) -> CommLedger:
    led = CommLedger()
    for _ in range(iterations):
        r1, r2 = led.next_round(), led.next_round()
        for c in range(2):
            led.log_bytes(c, "up", "reps", BATCH * REP_DIM * 4, round=r1)
            led.log_bytes(c, "down", "grads", BATCH * REP_DIM * 4, round=r2)
    return led


def one_shot_ledger(n_o: int) -> CommLedger:
    led = CommLedger()
    r1, r2, r3 = led.next_round(), led.next_round(), led.next_round()
    for c in range(2):
        led.log_bytes(c, "up", "reps", n_o * REP_DIM * 4, round=r1)
        led.log_bytes(c, "down", "grads", n_o * REP_DIM * 4 + 4, round=r2)
        led.log_bytes(c, "up", "reps2", n_o * REP_DIM * 4, round=r3)
    return led


def few_shot_ledger(n_o: int, n_u: int) -> CommLedger:
    led = one_shot_ledger(n_o)
    r3 = max(e.round for e in led.events)
    r4, r5 = led.next_round(), led.next_round()
    for c in range(2):
        led.log_bytes(c, "up", "reps_unaligned", n_u * REP_DIM * 4, round=r3)
        led.log_bytes(c, "down", "probs", n_u * 4, round=r4)
        led.log_bytes(c, "up", "reps_final", n_o * REP_DIM * 4, round=r5)
    return led


def engine_paths_cross_check() -> None:
    """Train one tiny one-shot session per engine path; assert identical
    ledgers (and the paper's 3 comm times) out of the shared engine."""
    import jax

    from repro.core import ProtocolConfig, SSLConfig, run_one_shot
    from repro.data import make_tabular_credit, make_vfl_partition
    from repro.models import make_mlp_extractor

    x, y = make_tabular_credit(jax.random.PRNGKey(0), 600)
    split = make_vfl_partition(x[:, :22], y, overlap_size=64,
                               feature_sizes=[11, 11], seed=1)
    ext = [make_mlp_extractor(rep_dim=8, hidden=(16,)) for _ in range(2)]
    ssl = [SSLConfig(modality="tabular")] * 2
    ledgers = {}
    for mode in ("vmap", "python"):
        cfg = ProtocolConfig(client_epochs=2, server_epochs=3, engine_mode=mode)
        res = run_one_shot(jax.random.PRNGKey(1), split, ext, ssl, cfg)
        assert res.diagnostics["engine_path"] == mode
        ledgers[mode] = res.ledger
    v, p = ledgers["vmap"], ledgers["python"]
    assert v.total_bytes() == p.total_bytes(), (v.total_bytes(), p.total_bytes())
    assert v.comm_times() == p.comm_times() == 3
    assert v.by_tag() == p.by_tag()
    print(f"comm/engine_paths_agree,0,"
          f"bytes={v.total_bytes()};times={v.comm_times()}")


def main() -> None:
    # the paper's Tab. 1 iteration counts per overlap size
    paper_iters = {256: 4000, 512: 8000, 1024: 16000, 2048: 32000}
    total_cifar = 50000
    print("name,us_per_call,derived")
    for n_o, iters in paper_iters.items():
        van = vanilla_ledger(iters)
        one = one_shot_ledger(n_o)
        n_u = (total_cifar - n_o) // 2
        few = few_shot_ledger(n_o, n_u)
        ratio = van.total_bytes() / one.total_bytes()
        print(f"comm/vanilla/overlap{n_o},0,"
              f"mb={van.total_megabytes():.1f};times={van.comm_times()}")
        print(f"comm/one_shot/overlap{n_o},0,"
              f"mb={one.total_megabytes():.2f};times={one.comm_times()}")
        print(f"comm/few_shot/overlap{n_o},0,"
              f"mb={few.total_megabytes():.2f};times={few.comm_times()}")
        print(f"comm/reduction/overlap{n_o},0,ratio={ratio:.0f}x")
        assert one.comm_times() == 3 and few.comm_times() == 5
        assert ratio > 300, ratio
    engine_paths_cross_check()


if __name__ == "__main__":
    main()
