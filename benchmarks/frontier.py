"""The comm-accuracy frontier: every method x every scenario, one artifact.

Reproduces the paper's comparative claims (Tab. 1-4 ordering: one-shot /
few-shot VFL vs iterative VFL under limited overlap) as a machine-checkable
benchmark. For each scenario in the registry selection it runs

    one_shot   -- Alg. 1 (3 comm times)
    few_shot   -- Alg. 2 (5 comm times)
    iterative  -- SplitNN-style vanilla VFL (2 comm times / iteration)
    fedcvt     -- FedCVT-style semi-supervised cross-view baseline

over ``--seeds N`` seeds (default 1). The paper's headline claims are
*statistical* — orderings that hold across runs, not at one seed — so the
sweep emits one row per (scenario, method, seed) plus, for N > 1, one
AGGREGATE row per (scenario, method) carrying metric mean/std/min/max.

Execution is GROUPED (DESIGN.md §12): the scenario selection is first
partitioned by ``scenarios.group_scenarios`` into stackable buckets —
entries whose party semantics (the engine's ``parties_are_homogeneous``
predicate, party position by party position), split shapes, and training
budgets all match — and each group's C scenarios × S seeds go through
``repro.core.protocol.run_scenarios_seeds`` as ONE folded sweep per
method: the protocol methods on the vmapped S·C·K client axis (DESIGN.md
§10), the iterative baselines as one ``vmap``-of-scan over S·C stacked
whole-session carries (DESIGN.md §11) — with zero fresh compiled-session
builds beyond each group's first member, so catalog coverage grows while
wall-clock grows far sublinearly.

Each row records metric (AUC or accuracy), ledger bytes, comm times,
wall-clock (per-seed rows: the method's whole-GROUP sweep wall amortized
over its C×S entries), ``group_size`` + ``scenario_fold`` + ``seed_fold``
(the partitioner's ground truth vs the fold the runner actually
executed), and ``cache_misses`` — fresh compiled-session builds the
method's whole group sweep triggered (the engine-wide session-cache
counters of DESIGN.md §9; ``jax.jit`` may still re-specialize a cached
session per input shape, so this counts trace-level program builds, not
individual XLA compilations). The blob-level ``session_cache`` field
carries the per-domain hit/miss totals and ``groups`` the partition.

CI wiring (.github/workflows/ci.yml, job ``bench-frontier`` — one of five
parallel bench legs; ``bench-kernels`` / ``bench-sharded`` /
``bench-faults`` re-run this module on focused ``--scenarios`` slices
with ``--use-kernels`` / ``--devices 2`` / the fault/* family, and
``bench-serving`` runs ``benchmarks.serving``)::

    REPRO_ENGINE_MODE=vmap python -m benchmarks.frontier \
        --smoke --seeds 2 --check-gate

``--smoke`` runs the FULL registry catalog at CI-tractable smoke sizes
(grouped execution is what makes that affordable); the scheduled nightly
tier (ci.yml job ``bench-frontier-nightly``) runs the frontier-tagged set
at paper sizes with ``--seeds 4``. ``--check-gate`` then enforces the
paper's headline ordering on the fresh results, per baseline-listed
scenario with overlap<=64 (dominance claims are pinned per scenario in
``frontier_baseline.json``; unlisted scenarios get only the invariance
and fold-discipline checks):

* bytes: one-shot must move >= 100x fewer bytes than iterative (bytes are
  shape-functions — seed-invariant, asserted by run_seeds);
* MEAN margin: mean over seeds of (one-shot metric - iterative metric)
  must clear the scenario's ``min_mean_margin`` floor from
  ``benchmarks/frontier_baseline.json`` (default: > 0);
* WORST seed: no single seed's margin may fall below ``min_worst_margin``
  (default: >= 0 — one-shot never loses a seed);
* FEW-SHOT margins, same two statistics against the
  ``fewshot_min_mean_margin`` / ``fewshot_min_worst_margin`` floors —
  few-shot is the framework's accuracy ceiling, so its comparative claim
  is gated alongside one-shot's;
* one-shot's ledger bytes must not regress above the recorded baseline.

Under ``REPRO_ENGINE_MODE=vmap`` it additionally requires every one-shot
AND few-shot per-seed row to have trained on the vmapped engine path,
every iterative/fedcvt per-seed row to have run the seed-batched ``scan``
fold, and — on every row — ``seed_fold`` to cover the sweep's seed count
and ``scenario_fold`` to equal the row's recorded ``group_size`` (the
grouped sweep must not silently degrade to per-scenario loops).
``vmap_eligible`` comes from the engine's own homogeneity predicate
(``engine.parties_are_homogeneous`` — apply-fn identity, not the old
shape heuristic, which would wrongly gate equal-dim model-zoo scenarios
whose Python path is legitimate); the scan fold needs no homogeneity, so
the iterative check is unconditional.

``--devices N`` (DESIGN.md §14) shards every folded sweep's stacked
S·C·K axis over an N-device launch mesh (forcing N host devices first on
CPU-only machines); rows record ``device_fold`` and the blob the mesh,
and ``--check-gate`` then also requires every folded row to have actually
sharded (``device_fold == N``).

Fault-injected scenarios (DESIGN.md §16) sweep like any others — the
catalog's fault/* members attach a ``FaultSpec`` and the group runner
forwards the C×S fault grid to ``run_scenarios_seeds`` — and the gate
adds the graceful-degradation floors of :func:`_check_fault_rows`: a
gated FULL sweep must contain fault rows at all, dropout rows must lose
exactly one party (with ledger-visible retry cost on the iterative
methods), and each faulted scenario's one-shot mean may trail its
fault-free twin by at most ``max_oneshot_drop`` (``fault_families`` in
``frontier_baseline.json``).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax

from repro import engine, scenarios
from repro.core import IterativeConfig, ProtocolConfig
from repro.core import rows as result_rows
from repro.core import runners as runner_registry
from repro.core.protocol import run_scenarios_seeds
from repro.engine import session_cache_stats, session_cache_stats_by_domain

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "frontier_baseline.json")

METHODS = ("one_shot", "few_shot", "iterative", "fedcvt")


def _aggregate_row(seed_rows) -> dict:
    """One (scenario, method) summary row over the per-seed rows: the mean
    metric doubles as ``metric`` so every consumer of the per-seed schema
    can read aggregate rows too."""
    metrics = [r["metric"] for r in seed_rows]
    mean = sum(metrics) / len(metrics)
    var = sum((m - mean) ** 2 for m in metrics) / len(metrics)
    row = dict(seed_rows[0])
    row.update(
        seed="aggregate",
        aggregate=True,
        num_seeds=len(seed_rows),
        metric=mean,
        metric_mean=mean,
        metric_std=var ** 0.5,
        metric_min=min(metrics),
        metric_max=max(metrics),
        wall_s=round(sum(r["wall_s"] for r in seed_rows), 2),
    )
    paths = {r.get("engine_path") for r in seed_rows}
    if len(paths) != 1:
        row.pop("engine_path", None)   # mixed per-seed paths: don't claim one
    return row


def _runner_cfgs(spec, methods=METHODS, devices=None,
                 use_kernels: bool = False) -> dict:
    """Resolve every method through THE runner registry
    (``repro.core.runners``): the entry supplies the runner callable, its
    ``kind`` picks the config family the scenario budgets parameterize.
    ``devices`` threads the launch mesh (DESIGN.md §14) into both config
    families so every folded sweep shards its stacked S·C·K axis;
    ``use_kernels`` flips the protocol methods onto the Pallas kernel
    route (batched grids over the same stacked axis, DESIGN.md §15 — the
    iterative baselines have no kernel-served hot-spot, so their config is
    untouched)."""
    pcfg = ProtocolConfig(
        client_epochs=spec.budget("client_epochs", 8),
        server_epochs=spec.budget("server_epochs", 30),
        mesh=devices,
        use_kernels=use_kernels,
    )
    if spec.fewshot_threshold is not None:
        pcfg = dataclasses.replace(pcfg,
                                   fewshot_threshold=spec.fewshot_threshold)
    icfg = IterativeConfig(iterations=spec.budget("iterations", 300),
                           mesh=devices)
    cfg_by_kind = {"protocol": pcfg, "iterative": icfg}
    return {m: (runner_registry.get(m).runner,
                cfg_by_kind[runner_registry.get(m).kind])
            for m in methods}


def build_bundles(spec, seeds, smoke: bool):
    """One built bundle per seed of one scenario."""
    return [scenarios.build(spec, seed=s, smoke=smoke) for s in seeds]


def run_scenario_group(bundles_per_scenario, seeds, methods=METHODS,
                       devices=None, use_kernels: bool = False):
    """Run every method on one partitioner GROUP of scenarios over all
    ``seeds``: each method's whole group — C scenarios × S seeds — goes
    through ``run_scenarios_seeds`` as ONE folded sweep (DESIGN.md §12;
    a single scenario is simply the C = 1 width). ``bundles_per_scenario``
    is the C×S grid of built bundles (``[c][s]``). ``devices`` shards each
    folded sweep's stacked axis over that many devices (DESIGN.md §14) —
    every row's ``device_fold`` diagnostic records whether it did. Returns
    result rows.
    """
    specs = [bs[0].spec for bs in bundles_per_scenario]
    group_size = len(specs)
    runner_cfgs = _runner_cfgs(specs[0], methods, devices=devices,
                               use_kernels=use_kernels)
    # the engine's own fast-path precondition: apply-fn identity + equal
    # SSL configs + equal per-party feature shapes. Heterogeneous feature
    # blocks (e.g. credit/feature-skew) — or equal-dim parties with
    # *different* architectures — legitimately take the Python fallback,
    # so the engine-path gate must skip those rows. ONE decision per
    # group: the partitioner's signature makes party semantics uniform
    # across members, so scenario 0 speaks for all of them
    b0 = bundles_per_scenario[0][0]
    vmap_eligible = engine.parties_are_homogeneous(
        b0.extractors, b0.ssl_cfgs, [x.shape for x in b0.split.aligned])
    # a group carrying any FaultSpec threads the C×S fault grid through the
    # SAME folded sweep (DESIGN.md §16): faults are per-entry data, excluded
    # from the fold signature, so fault/* members and their fault-free twin
    # stack into one program
    fault_kw = {}
    if any(spec.fault is not None for spec in specs):
        fault_kw["faults"] = [[spec.fault for _ in seeds] for spec in specs]
    rows = []
    for method in methods:
        runner, cfg = runner_cfgs[method]
        t0 = time.time()
        misses0 = session_cache_stats()["misses"]
        results = run_scenarios_seeds(
            runner,
            [[jax.random.PRNGKey(s) for s in seeds] for _ in specs],
            [[b.split for b in bs] for bs in bundles_per_scenario],
            [[b.extractors for b in bs] for bs in bundles_per_scenario],
            [[b.ssl_cfgs for b in bs] for bs in bundles_per_scenario],
            cfg, **fault_kw)
        wall = time.time() - t0
        misses = session_cache_stats()["misses"] - misses0
        for spec, scen_results in zip(specs, results):
            seed_rows = []
            for seed, res in zip(seeds, scen_results):
                # the one typed row builder every gate consumes
                # (repro.core.rows): summary_row() context rides along here
                row = result_rows.training_row(
                    res,
                    scenario=spec.name,
                    seed=seed,
                    method=method,
                    # whole-GROUP sweep wall, amortized per (scenario, seed)
                    wall_s=round(wall / (len(seeds) * group_size), 2),
                    cache_misses=misses,          # whole-group fresh builds
                    group_size=group_size,        # partitioner ground truth
                    vmap_eligible=vmap_eligible,
                    use_kernels=use_kernels,
                    overlap=spec.overlap,
                    num_parties=spec.num_parties,
                    modality=spec.modality,
                )
                seed_rows.append(row)
                print(
                    "{scenario:>18s} {method:>9s} s{seed:<2d} "
                    "{metric_name}={metric:.4f} bytes={comm_bytes:>10d} "
                    "times={comm_times:>6d} ({wall_s:.0f}s)".format(**row),
                    flush=True,
                )
            rows.extend(seed_rows)
            if len(seed_rows) > 1:
                agg = _aggregate_row(seed_rows)
                rows.append(agg)
                print(
                    "{scenario:>18s} {method:>9s} agg "
                    "{metric_name}={metric_mean:.4f}±{metric_std:.4f} "
                    "[{metric_min:.4f}, {metric_max:.4f}] "
                    "({wall_s:.0f}s total)".format(**agg),
                    flush=True,
                )
    return rows


def run_scenario(spec, seeds, smoke: bool, methods=METHODS, devices=None,
                 use_kernels: bool = False):
    """Run every method on ONE scenario over all ``seeds`` — the width-1
    group case of :func:`run_scenario_group`."""
    return run_scenario_group([build_bundles(spec, seeds, smoke)], seeds,
                              methods=methods, devices=devices,
                              use_kernels=use_kernels)


def _check_margins(name: str, method_rows: dict, its: dict, label: str,
                   min_mean: float, min_worst: float, problems: list) -> None:
    """Mean-margin + worst-seed dominance of one method over iterative."""
    shared_seeds = sorted(set(method_rows) & set(its))
    if not shared_seeds:
        return
    margins = {s: method_rows[s]["metric"] - its[s]["metric"]
               for s in shared_seeds}
    mean_margin = sum(margins.values()) / len(margins)
    if mean_margin <= min_mean:
        problems.append(
            f"{name}: {label} mean margin over iterative "
            f"{mean_margin:+.4f} <= floor {min_mean:+.4f} "
            f"(seeds {shared_seeds})"
        )
    worst_seed = min(margins, key=margins.get)
    if margins[worst_seed] < min_worst:
        problems.append(
            f"{name}: {label} worst-seed margin {margins[worst_seed]:+.4f} "
            f"(seed {worst_seed}) < floor {min_worst:+.4f}"
        )


def _check_fault_rows(per_seed, baseline, expect_faults: bool,
                      problems: list) -> None:
    """Graceful-degradation gate over the fault/* rows (DESIGN.md §16).

    Per ``fault_families`` entry in the baseline file: the whole family
    must be present (``required``); dropout rows must record one party
    lost (``parties_survived == K-1``) and — on the iterative methods —
    ledger-visible retry/timeout cost; every protocol fault row must carry
    ``degraded_metric``; and the one-shot MEAN metric of each faulted
    scenario may fall at most ``max_oneshot_drop`` below its fault-free
    twin's (``baseline_scenario``). A gated full sweep with ZERO fault
    rows is itself a violation (``expect_faults``) — degradation coverage
    must not silently vanish from CI, mirroring the missing-few-shot rule.
    """
    fams = baseline.get("fault_families", {})
    fault_rows = [r for r in per_seed if "fault_kind" in r]
    if not fault_rows:
        if expect_faults:
            problems.append(
                "no fault-injected rows in a gated sweep — the "
                "graceful-degradation gate cannot be evaluated (sweep the "
                "full catalog, or pass --scenarios explicitly for partial "
                "sweeps)"
            )
        return
    for fam, fspec in fams.items():
        rows_f = [r for r in fault_rows
                  if r["scenario"].startswith(fam + "/")]
        if not rows_f:
            continue
        present = {r["scenario"] for r in rows_f}
        missing = sorted(set(fspec.get("required", ())) - present)
        if missing:
            problems.append(
                f"fault family {fam!r}: scenarios {missing} missing from "
                f"the sweep — the degradation claim needs the whole family"
            )
        for r in rows_f:
            num_parties = r.get("num_parties")
            survived = r.get("parties_survived")
            if r.get("fault_kind") == "dropout":
                if survived != num_parties - 1:
                    problems.append(
                        f"{r['scenario']} seed {r['seed']}: {r['method']} "
                        f"dropout row records parties_survived={survived} "
                        f"(expected {num_parties - 1} of {num_parties})"
                    )
                if r["method"] in ("iterative", "fedcvt") \
                        and (r.get("fault_retry_rounds", 0) < 1
                             or r.get("fault_retry_bytes", 0) < 1):
                    problems.append(
                        f"{r['scenario']} seed {r['seed']}: {r['method']} "
                        f"dropout row shows no retry/timeout cost in the "
                        f"ledger (fault_retry_rounds="
                        f"{r.get('fault_retry_rounds')}, fault_retry_bytes="
                        f"{r.get('fault_retry_bytes')})"
                    )
            elif survived != num_parties:
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} "
                    f"{r.get('fault_kind')} row records "
                    f"parties_survived={survived} (expected {num_parties})"
                )
            if r["method"] in ("one_shot", "few_shot") \
                    and r.get("degraded_metric") is None:
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} fault "
                    f"row carries no degraded_metric"
                )
        base_name = fspec.get("baseline_scenario")
        max_drop = fspec.get("max_oneshot_drop")
        if base_name is None or max_drop is None:
            continue
        base_ones = [r["metric"] for r in per_seed
                     if r["scenario"] == base_name
                     and r["method"] == "one_shot"]
        if not base_ones:
            problems.append(
                f"fault family {fam!r}: fault-free twin {base_name!r} has "
                f"no one_shot rows to measure degradation against"
            )
            continue
        base_mean = sum(base_ones) / len(base_ones)
        for name in sorted(present - {base_name}):
            vals = [r["metric"] for r in fault_rows
                    if r["scenario"] == name and r["method"] == "one_shot"]
            if not vals:
                continue
            mean = sum(vals) / len(vals)
            if mean < base_mean - max_drop:
                problems.append(
                    f"{name}: one-shot degraded mean metric {mean:.4f} "
                    f"fell more than {max_drop:.3f} below the fault-free "
                    f"twin {base_name} ({base_mean:.4f}) — graceful "
                    f"degradation broke"
                )


def check_gate(rows, baseline_path: str = BASELINE_PATH,
               devices=None, use_kernels: bool = False,
               expect_faults: bool = False) -> list:
    """The CI regression gate. Returns a list of violation strings.

    Point estimates upgraded to seed statistics: the one-shot-vs-iterative
    AND few-shot-vs-iterative orderings are enforced on the MEAN margin
    across seeds plus a worst-seed floor, instead of a single seed's
    (possibly lucky) point comparison — few-shot is the framework's
    accuracy ceiling, so its margins are gated alongside one-shot's.

    ``devices`` (a sharded ``--devices N`` sweep) additionally requires
    every per-seed row that trained on a folded engine path ("vmap" or
    "scan") to record ``device_fold == devices`` — the mesh must not be
    silently dropped — and every Python-fallback row to record 1.

    ``use_kernels`` (a ``--use-kernels`` sweep) requires the kernel path to
    have kept the fold (DESIGN.md §15): every stackable protocol row must
    record ``kernel_fold == seed_fold · scenario_fold · num_parties`` (the
    step-③ k-means fold over the whole flat S·C·K batch — no per-entry
    fallback) and every few-shot row ``sdpa_fold == seed_fold ·
    scenario_fold`` (③' folded over the stacked seed axis).

    ``expect_faults`` (set by full gated sweeps) additionally runs the
    graceful-degradation gate over the fault/* rows — and treats a sweep
    with ZERO fault rows as a violation (:func:`_check_fault_rows`).
    """
    problems = []
    per_seed = [r for r in rows if not r.get("aggregate")]
    scenario_names = sorted({r["scenario"] for r in per_seed})

    with open(baseline_path) as fh:
        baseline = json.load(fh)

    _check_fault_rows(per_seed, baseline, expect_faults, problems)

    if use_kernels:
        for r in per_seed:
            if r["method"] not in ("one_shot", "few_shot") \
                    or not r.get("vmap_eligible", False):
                continue   # ragged party zoos legitimately fall back
            flat = r.get("seed_fold", 1) * r.get("scenario_fold", 1)
            want_km = flat * r.get("num_parties", 1)
            if r.get("kernel_fold") != want_km:
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} ran "
                    f"kernel_fold={r.get('kernel_fold')} under --use-kernels "
                    f"(expected {want_km} = seed_fold x scenario_fold x "
                    f"num_parties"
                    + (f"; fallback: {r['kernel_fallback']!r}"
                       if r.get("kernel_fallback") else "")
                    + ") — the step-③ k-means dropped the batched "
                    f"kernel grid"
                )
            if r["method"] == "few_shot" and r.get("sdpa_fold") != flat:
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: few_shot ran "
                    f"sdpa_fold={r.get('sdpa_fold')} under --use-kernels "
                    f"(expected {flat}) — ③' degraded to a per-seed loop"
                )

    if devices is not None:
        for r in per_seed:
            want = devices if r.get("engine_path") in ("vmap", "scan") else 1
            if r.get("device_fold") != want:
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} on "
                    f"engine_path={r.get('engine_path')!r} recorded "
                    f"device_fold={r.get('device_fold')} under "
                    f"--devices {devices} (expected {want}) — the stacked "
                    f"axis did not shard over the launch mesh"
                )

    if os.environ.get("REPRO_ENGINE_MODE", "") == "vmap":
        # the CI matrix forces the fast path: every protocol method whose
        # party zoo CAN stack must actually have trained on it — on every
        # seed — including few-shot phase ⑤', whose masked sessions stack
        # at any ragged per-party gate counts (heterogeneous party zoos are
        # exempt: the Python fallback is the correct path there)
        for r in per_seed:
            if r["method"] in ("one_shot", "few_shot") \
                    and r.get("vmap_eligible", False) \
                    and r.get("engine_path") != "vmap":
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} trained "
                    f"on engine_path={r.get('engine_path')!r} under "
                    f"REPRO_ENGINE_MODE=vmap"
                )
            # the iterative baselines must have run the seed-batched scan
            # fold (DESIGN.md §11) — the scan session needs no party
            # homogeneity, so no vmap_eligible exemption applies
            if r["method"] in ("iterative", "fedcvt") \
                    and r.get("engine_path") != "scan":
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} trained "
                    f"on engine_path={r.get('engine_path')!r} under "
                    f"REPRO_ENGINE_MODE=vmap (expected the seed-batched "
                    f"'scan' fold)"
                )
        # engine_path=="scan" alone cannot distinguish the fold from the
        # per-seed fallback loop — seed_fold (the width the runner actually
        # folded) must cover every seed of the sweep
        num_sweep_seeds = len({r["seed"] for r in per_seed})
        for r in per_seed:
            fold = r.get("seed_fold")
            if fold is not None and fold != num_sweep_seeds:
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} ran "
                    f"seed_fold={fold} — the {num_sweep_seeds}-seed sweep "
                    f"fell back to the per-seed loop instead of the "
                    f"DESIGN.md §10-11 fold"
                )
            # ... and scenario_fold must cover the row's whole partitioner
            # group: group_size is the ground truth the bench recorded, so
            # a mismatch means the grouped sweep silently degraded to the
            # per-scenario loop (e.g. a shape drift broke the stack)
            gsize = r.get("group_size")
            if gsize is not None and r.get("scenario_fold") != gsize:
                problems.append(
                    f"{r['scenario']} seed {r['seed']}: {r['method']} ran "
                    f"scenario_fold={r.get('scenario_fold')} against a "
                    f"size-{gsize} group — the grouped sweep fell back to "
                    f"the per-scenario loop instead of the DESIGN.md §12 "
                    f"fold"
                )

    for name in scenario_names:
        ones = {r["seed"]: r for r in per_seed
                if r["scenario"] == name and r["method"] == "one_shot"}
        fews = {r["seed"]: r for r in per_seed
                if r["scenario"] == name and r["method"] == "few_shot"}
        its = {r["seed"]: r for r in per_seed
               if r["scenario"] == name and r["method"] == "iterative"}
        if not ones:
            continue
        one0 = next(iter(ones.values()))
        one_bytes = {r["comm_bytes"] for r in ones.values()}
        if len(one_bytes) != 1:
            problems.append(
                f"{name}: one-shot bytes differ across seeds "
                f"{sorted(one_bytes)} — communication must be seed-invariant"
            )
        # dominance claims (bytes ratio + margins + bytes regression) are
        # pinned per scenario in the baseline file: scenarios without an
        # entry — e.g. the full smoke catalog's image/credit rows, whose
        # iteration budgets make no 100x bytes claim — only get the
        # seed-invariance and fold-discipline checks above
        base = baseline.get(name)
        if base is None:
            continue
        if base.get("one_shot_bytes") is not None \
                and one0["comm_bytes"] > base["one_shot_bytes"]:
            problems.append(
                f"{name}: one-shot bytes regressed "
                f"{one0['comm_bytes']} > baseline {base['one_shot_bytes']}"
            )
        if not its or one0["overlap"] > 64:
            continue
        it0 = next(iter(its.values()))
        ratio = it0["comm_bytes"] / max(one0["comm_bytes"], 1)
        if ratio < 100.0:
            problems.append(
                f"{name}: one-shot bytes advantage {ratio:.0f}x < 100x"
            )
        _check_margins(name, ones, its, "one-shot",
                       base.get("min_mean_margin", 0.0),
                       base.get("min_worst_margin", 0.0), problems)
        if not fews:
            # a margin that was never measured must not read as a pass
            problems.append(
                f"{name}: no few_shot rows — the few-shot margin gate "
                f"cannot be evaluated (run all METHODS, or drop --check-gate "
                f"for partial sweeps)"
            )
        _check_margins(name, fews, its, "few-shot",
                       base.get("fewshot_min_mean_margin", 0.0),
                       base.get("fewshot_min_worst_margin", 0.0), problems)
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="the full catalog at CI-tractable smoke sizes "
                    "(grouped execution, DESIGN.md §12)")
    ap.add_argument("--seed", type=int, default=0, help="first seed")
    ap.add_argument(
        "--seeds",
        type=int,
        default=1,
        help="number of seeds per scenario (seed .. seed+N-1), executed "
        "seed-batched through the engine (DESIGN.md §10)",
    )
    ap.add_argument("--out", default="BENCH_frontier.json")
    ap.add_argument(
        "--scenarios",
        nargs="+",
        default=None,
        help="explicit scenario names (default: tag-based selection)",
    )
    ap.add_argument(
        "--check-gate",
        action="store_true",
        help="enforce the mean-margin/worst-seed dominance + "
        "bytes-regression gate",
    )
    ap.add_argument("--baseline", default=BASELINE_PATH)
    ap.add_argument(
        "--use-kernels",
        action="store_true",
        help="route the protocol methods' hot-spots (step-③ k-means, "
        "few-shot ③' SDPA) through the batched Pallas kernel grids "
        "(DESIGN.md §15); --check-gate then also pins the kernel-fold "
        "discipline (kernel_fold/sdpa_fold equal the stacked widths)",
    )
    ap.add_argument(
        "--devices",
        type=int,
        default=None,
        help="shard every folded sweep's stacked S*C*K axis over this many "
        "devices (DESIGN.md §14); on CPU hosts the device pool is forced "
        "via --xla_force_host_platform_device_count before jax initializes",
    )
    args = ap.parse_args(argv)

    if args.devices is not None and args.devices > 1:
        # set XLA_FLAGS BEFORE the first backend touch (any device_count()
        # call initializes it and freezes the visible pool) — harmless on
        # non-CPU platforms, where the flag only affects the host backend
        from repro.launch.mesh import forced_host_devices

        forced_host_devices(args.devices)
        if jax.device_count() < args.devices:
            print(f"--devices {args.devices} requested but only "
                  f"{jax.device_count()} visible (was the jax backend "
                  f"already initialized before --devices took effect?)",
                  file=sys.stderr)
            return 2

    if args.scenarios:
        specs = [scenarios.get(n) for n in args.scenarios]
    elif args.smoke:
        # the FULL catalog at smoke sizes: grouped execution (DESIGN.md
        # §12) is what makes every-scenario coverage affordable per-PR —
        # each stackable family compiles once, not once per scenario
        specs = [scenarios.get(n) for n in scenarios.names()]
    else:
        specs = scenarios.by_tag("frontier")
    seeds = list(range(args.seed, args.seed + args.seeds))

    t0 = time.time()
    bundles = [build_bundles(spec, seeds, smoke=args.smoke) for spec in specs]
    groups = scenarios.group_scenarios(
        [(bs[0].spec, bs[0]) for bs in bundles])
    for g in groups:
        print(f"group[{g.size}]: {', '.join(g.names)}", flush=True)
    rows = []
    for g in groups:
        rows.extend(run_scenario_group([bundles[i] for i in g.indices],
                                       seeds, devices=args.devices,
                                       use_kernels=args.use_kernels))

    mesh = engine.resolve_mesh(args.devices)
    blob = {
        "mode": "smoke" if args.smoke else "full",
        "seed": args.seed,
        "seeds": seeds,
        "devices": args.devices,
        "use_kernels": args.use_kernels,
        "mesh": None if mesh is None else {
            "axis_names": list(mesh.axis_names),
            "shape": list(mesh.devices.shape)},
        "groups": [{"scenarios": g.names, "size": g.size} for g in groups],
        "wall_s": round(time.time() - t0, 2),
        "session_cache": session_cache_stats_by_domain(),
        "rows": rows,
    }
    with open(args.out, "w") as fh:
        json.dump(blob, fh, indent=2)
    print(f"wrote {args.out}: {len(rows)} rows in {blob['wall_s']:.0f}s")

    if args.check_gate:
        # an explicit --scenarios list is a partial sweep by construction;
        # tag/smoke selections must carry the fault family (DESIGN.md §16)
        problems = check_gate(rows, args.baseline, devices=args.devices,
                              use_kernels=args.use_kernels,
                              expect_faults=args.scenarios is None)
        if problems:
            for p in problems:
                print(f"GATE VIOLATION: {p}", file=sys.stderr)
            return 1
        print("gate: one-shot AND few-shot dominate iterative (bytes >=100x, "
              "mean margin + worst seed), engine paths as forced, fault/* "
              "degradation within bounds, and bytes match the recorded "
              "baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
