"""The comm-accuracy frontier: every method x every scenario, one artifact.

Reproduces the paper's comparative claims (Tab. 1-4 ordering: one-shot /
few-shot VFL vs iterative VFL under limited overlap) as a machine-checkable
benchmark. For each scenario in the registry selection it runs

    one_shot   -- Alg. 1 (3 comm times)
    few_shot   -- Alg. 2 (5 comm times)
    iterative  -- SplitNN-style vanilla VFL (2 comm times / iteration)
    fedcvt     -- FedCVT-style semi-supervised cross-view baseline

and writes ``BENCH_frontier.json`` rows with per-method metric (AUC or
accuracy), ledger bytes, comm times, wall-clock, and ``cache_misses`` —
how many fresh compiled-session builds the method triggered (the
engine-wide session-cache counters of DESIGN.md §9; ``jax.jit`` may still
re-specialize a cached session per input shape, so this counts trace-level
program builds, not individual XLA compilations). The blob-level
``session_cache`` field carries the per-domain hit/miss totals, so a
sweep's no-recompile behaviour across seeds/scenarios is visible in the
artifact.

CI wiring (.github/workflows/ci.yml, job ``bench-smoke``)::

    REPRO_ENGINE_MODE=vmap python -m benchmarks.frontier --smoke --check-gate

``--smoke`` restricts to the registry's ``smoke``-tagged scenarios at
CI-tractable sizes (< 3 min). ``--check-gate`` then enforces the paper's
headline ordering on the fresh results: one-shot must dominate the
iterative baseline on BOTH bytes (>= 100x less) and metric for every
overlap<=64 scenario, and one-shot's ledger bytes must not regress above
the recorded baseline (``benchmarks/frontier_baseline.json``). Under
``REPRO_ENGINE_MODE=vmap`` it additionally requires every one-shot AND
few-shot row to have trained on the vmapped engine path (few-shot's
masked fixed-shape phase ⑤' no longer downgrades at ragged gate counts).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax

from repro import scenarios
from repro.core import (
    IterativeConfig,
    ProtocolConfig,
    run_fedcvt,
    run_few_shot,
    run_one_shot,
    run_vanilla,
)
from repro.engine import session_cache_stats, session_cache_stats_by_domain

BASELINE_PATH = os.path.join(os.path.dirname(__file__), "frontier_baseline.json")

METHODS = ("one_shot", "few_shot", "iterative", "fedcvt")


def run_scenario(spec, seed: int, smoke: bool, methods=METHODS):
    """Run every method on one scenario; returns a list of result rows."""
    bundle = scenarios.build(spec, seed=seed, smoke=smoke)
    spec = bundle.spec
    pcfg = ProtocolConfig(
        client_epochs=spec.budget("client_epochs", 8),
        server_epochs=spec.budget("server_epochs", 30),
    )
    if spec.fewshot_threshold is not None:
        pcfg = dataclasses.replace(pcfg,
                                   fewshot_threshold=spec.fewshot_threshold)
    icfg = IterativeConfig(iterations=spec.budget("iterations", 300))
    runners = {
        "one_shot": lambda k: run_one_shot(
            k, bundle.split, bundle.extractors, bundle.ssl_cfgs, pcfg
        ),
        "few_shot": lambda k: run_few_shot(
            k, bundle.split, bundle.extractors, bundle.ssl_cfgs, pcfg
        ),
        "iterative": lambda k: run_vanilla(
            k, bundle.split, bundle.extractors, bundle.ssl_cfgs, icfg
        ),
        "fedcvt": lambda k: run_fedcvt(
            k, bundle.split, bundle.extractors, bundle.ssl_cfgs, icfg
        ),
    }
    # the vmap fast path needs one stacked shape across parties; unequal
    # per-party feature blocks (e.g. credit/feature-skew) legitimately take
    # the Python fallback, so the engine-path gate must skip those rows
    vmap_eligible = len({x.shape[1:] for x in bundle.split.aligned}) == 1
    rows = []
    for method in methods:
        t0 = time.time()
        misses0 = session_cache_stats()["misses"]
        res = runners[method](jax.random.PRNGKey(seed))
        row = res.summary_row()
        row.update(
            scenario=spec.name,
            seed=seed,
            method=method,
            wall_s=round(time.time() - t0, 2),
            cache_misses=session_cache_stats()["misses"] - misses0,
            vmap_eligible=vmap_eligible,
            overlap=spec.overlap,
            num_parties=spec.num_parties,
            modality=spec.modality,
        )
        rows.append(row)
        print(
            "{scenario:>18s} {method:>9s} {metric_name}={metric:.4f} "
            "bytes={comm_bytes:>10d} times={comm_times:>6d} "
            "({wall_s:.0f}s)".format(**row),
            flush=True,
        )
    return rows


def check_gate(rows, baseline_path: str = BASELINE_PATH) -> list:
    """The CI regression gate. Returns a list of violation strings."""
    problems = []
    by_key = {(r["scenario"], r["method"]): r for r in rows}
    scenario_names = sorted({r["scenario"] for r in rows})

    with open(baseline_path) as fh:
        baseline = json.load(fh)

    if os.environ.get("REPRO_ENGINE_MODE", "") == "vmap":
        # the CI matrix forces the fast path: every protocol method whose
        # party zoo CAN stack must actually have trained on it — including
        # few-shot phase ⑤', whose masked sessions stack at any ragged
        # per-party gate counts (heterogeneous feature splits are exempt:
        # the Python fallback is the correct path there)
        for r in rows:
            if r["method"] in ("one_shot", "few_shot") \
                    and r.get("vmap_eligible", False) \
                    and r.get("engine_path") != "vmap":
                problems.append(
                    f"{r['scenario']}: {r['method']} trained on engine_path="
                    f"{r.get('engine_path')!r} under REPRO_ENGINE_MODE=vmap"
                )

    for name in scenario_names:
        one = by_key.get((name, "one_shot"))
        it = by_key.get((name, "iterative"))
        if one is None:
            continue
        base = baseline.get(name)
        if base is not None and one["comm_bytes"] > base["one_shot_bytes"]:
            problems.append(
                f"{name}: one-shot bytes regressed "
                f"{one['comm_bytes']} > baseline {base['one_shot_bytes']}"
            )
        if it is None or one["overlap"] > 64:
            continue
        ratio = it["comm_bytes"] / max(one["comm_bytes"], 1)
        if ratio < 100.0:
            problems.append(
                f"{name}: one-shot bytes advantage {ratio:.0f}x < 100x"
            )
        if one["metric"] < it["metric"]:
            problems.append(
                f"{name}: one-shot {one['metric']:.4f} below "
                f"iterative {it['metric']:.4f} at overlap {one['overlap']}"
            )
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="smoke-tagged scenarios only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="BENCH_frontier.json")
    ap.add_argument(
        "--scenarios",
        nargs="+",
        default=None,
        help="explicit scenario names (default: tag-based selection)",
    )
    ap.add_argument(
        "--check-gate",
        action="store_true",
        help="enforce the comm/accuracy dominance + bytes-regression gate",
    )
    ap.add_argument("--baseline", default=BASELINE_PATH)
    args = ap.parse_args(argv)

    if args.scenarios:
        specs = [scenarios.get(n) for n in args.scenarios]
    elif args.smoke:
        specs = scenarios.by_tag("smoke")
    else:
        specs = scenarios.by_tag("frontier")

    t0 = time.time()
    rows = []
    for spec in specs:
        rows.extend(run_scenario(spec, args.seed, smoke=args.smoke))

    blob = {
        "mode": "smoke" if args.smoke else "full",
        "seed": args.seed,
        "wall_s": round(time.time() - t0, 2),
        "session_cache": session_cache_stats_by_domain(),
        "rows": rows,
    }
    with open(args.out, "w") as fh:
        json.dump(blob, fh, indent=2)
    print(f"wrote {args.out}: {len(rows)} rows in {blob['wall_s']:.0f}s")

    if args.check_gate:
        problems = check_gate(rows, args.baseline)
        if problems:
            for p in problems:
                print(f"GATE VIOLATION: {p}", file=sys.stderr)
            return 1
        print("gate: one-shot dominates iterative (bytes >=100x, metric) "
              "and bytes match the recorded baseline")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
