"""Benchmark harness — one entry per paper table/figure plus the kernel and
roofline reports. Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run            # CI-speed defaults
  PYTHONPATH=src python -m benchmarks.run --full     # paper-scale (hours)

Sections:
  table1/*    — Tab. 1  (accuracy + comm vs overlap, image VFL)
  credit/*    — Fig. 6/7 (AUC + comm, tabular VFL)
  comm/*      — Tab. 1 communication columns at the paper's exact scale
  kernel/*    — Pallas kernel hot-spot shapes vs jnp oracle
  roofline/*  — §Roofline dominant term per (arch × shape × mesh), from the
                dry-run records in experiments/dryrun (run dryrun --all first)
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--skip", nargs="*", default=[])
    args, _ = ap.parse_known_args()

    from benchmarks import (ablation_fewshot, comm_cost, credit, frontier,
                            kernels_bench, table1)

    sections = []
    if "comm" not in args.skip:
        sections.append(("comm_cost", comm_cost.main, []))
    if "frontier" not in args.skip:
        argv = [] if args.full else ["--smoke"]
        sections.append(("frontier", frontier.main, argv))
    if "kernels" not in args.skip:
        sections.append(("kernels", kernels_bench.main, []))
    if "table1" not in args.skip:
        argv = ["--full"] if args.full else ["--fast"]
        sections.append(("table1", table1.main, argv))
    if "credit" not in args.skip:
        argv = ["--full"] if args.full else ["--fast"]
        sections.append(("credit", credit.main, argv))
    if "ablation" not in args.skip:
        argv = ["--fast"] if not args.full else []
        sections.append(("ablation_fewshot", ablation_fewshot.main, argv))
    if "roofline" not in args.skip:
        def _roofline():
            from benchmarks import roofline_table
            roofline_table.main()
        sections.append(("roofline", _roofline, []))

    for name, fn, argv in sections:
        print(f"\n# ==== {name} ====", flush=True)
        old_argv = sys.argv
        sys.argv = [name] + argv
        try:
            fn()
        except Exception:
            traceback.print_exc()
            print(f"{name}/FAILED,0,error")
        finally:
            sys.argv = old_argv


if __name__ == "__main__":
    main()
