"""§Roofline table: read the dry-run records and emit the per-(arch × shape ×
mesh) three-term roofline with bottleneck + usefulness ratio."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.roofline.analysis import roofline_terms

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def load_records(dryrun_dir: str = DRYRUN_DIR) -> List[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(dryrun_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def summarize(rec: dict) -> Dict:
    ha = rec.get("hlo_analysis", {})
    if "dot_flops" not in ha:
        return {}
    terms = roofline_terms({
        "dot_flops": ha["dot_flops"],
        "traffic_bytes": ha["traffic_bytes"],
        "collective_bytes": ha["total_collective_bytes"],
    })
    n_dev = 1
    for v in rec["mesh"].split("x"):
        n_dev *= int(v)
    mf = rec.get("model_flops_global", 0.0)
    useful = (mf / n_dev) / ha["dot_flops"] if ha["dot_flops"] else 0.0
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "compute_s": terms["compute_s"], "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "bottleneck": terms["bottleneck"],
        "useful_ratio": useful,
        "params_b": rec.get("num_params", 0) / 1e9,
    }


def markdown_table(rows: List[dict], mesh_filter: str = "16x16") -> str:
    head = ("| arch | shape | compute s | memory s | collective s | "
            "bottleneck | useful FLOPs ratio |")
    sep = "|---|---|---|---|---|---|---|"
    lines = [head, sep]
    for r in rows:
        if not r or r["mesh"] != mesh_filter:
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"**{r['bottleneck']}** | {r['useful_ratio']:.3f} |")
    return "\n".join(lines)


def main() -> None:
    rows = [summarize(r) for r in load_records()]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["mesh"]))
    print(markdown_table(rows, "16x16"))
    print()
    print("# multi-pod (2x16x16)")
    print(markdown_table(rows, "2x16x16"))
    # CSV for run.py
    print()
    for r in rows:
        dom = max(r["compute_s"], r["memory_s"], r["collective_s"])
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{dom * 1e6:.1f},bottleneck={r['bottleneck']}")


if __name__ == "__main__":
    main()
