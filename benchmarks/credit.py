"""Fig. 6/7 reproduction: AUC vs communication on credit-default tabular VFL
(10/13 feature split per FATE), overlap ∈ {1000, 2000} scaled by --fast."""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import (IterativeConfig, ProtocolConfig, SSLConfig,
                        run_fedbcd, run_fedcvt, run_few_shot, run_one_shot,
                        run_vanilla)
from repro.data import make_tabular_credit, make_vfl_partition
from repro.models import make_mlp_extractor


def run(overlaps, num_samples, iters, epochs, seed=0):
    x, y = make_tabular_credit(jax.random.PRNGKey(seed), num_samples)
    rows = []
    for n_o in overlaps:
        split = make_vfl_partition(x, y, overlap_size=n_o,
                                   feature_sizes=[10, 13], seed=seed + 1)
        mk = lambda: [make_mlp_extractor(rep_dim=32, hidden=(64,))
                      for _ in range(2)]
        ssl = [SSLConfig(modality="tabular")] * 2
        pcfg = ProtocolConfig(client_epochs=epochs, server_epochs=3 * epochs)
        icfg = IterativeConfig(iterations=iters)
        methods = {
            "vanilla": lambda: run_vanilla(jax.random.PRNGKey(2), split, mk(), ssl, icfg),
            "fedcvt": lambda: run_fedcvt(jax.random.PRNGKey(2), split, mk(), ssl, icfg),
            "fedbcd": lambda: run_fedbcd(jax.random.PRNGKey(2), split, mk(), ssl, icfg),
            "one_shot": lambda: run_one_shot(jax.random.PRNGKey(2), split, mk(), ssl, pcfg),
            "few_shot": lambda: run_few_shot(jax.random.PRNGKey(2), split, mk(), ssl, pcfg),
        }
        for name, fn in methods.items():
            t0 = time.time()
            res = fn()
            rows.append({"overlap": n_o, "method": name, "auc": res.metric,
                         "comm_times": res.ledger.comm_times(),
                         "comm_mb": res.ledger.total_megabytes(),
                         "wall_s": time.time() - t0})
            print(f"overlap={n_o:5d} {name:10s} auc={res.metric:.4f} "
                  f"times={rows[-1]['comm_times']:6d} "
                  f"mb={rows[-1]['comm_mb']:8.3f}", flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.full:
        rows = run([1000, 2000], 30000, 2000, 20)
    elif args.fast:
        rows = run([128], 1200, 60, 2)
    else:
        rows = run([200, 400], 3000, 300, 3)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"credit/{r['method']}/overlap{r['overlap']},"
              f"{r['wall_s'] * 1e6:.0f},"
              f"auc={r['auc']:.4f};comm_mb={r['comm_mb']:.3f};"
              f"comm_times={r['comm_times']}")


if __name__ == "__main__":
    main()
