"""Table 1 reproduction: accuracy + communication vs overlap size (image VFL).

Paper protocol at CPU-tractable synthetic scale: image halves, CNN
extractors, overlap ∈ {64, 128, 256} (paper: {256..2048} on CIFAR-10;
scale with --full on a real machine). Methods: vanilla, FedCVT, FedBCD,
one-shot, few-shot.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.core import (IterativeConfig, ProtocolConfig, SSLConfig,
                        run_fedbcd, run_fedcvt, run_few_shot, run_one_shot,
                        run_vanilla)
from repro.data import make_image_classification, make_vfl_partition
from repro.models import make_cnn_extractor


def run(overlaps, num_samples, iters, epochs, image_size=16, num_classes=10,
        seed=0):
    x, y = make_image_classification(jax.random.PRNGKey(seed), num_samples,
                                     num_classes=num_classes,
                                     image_size=image_size)
    rows = []
    for n_o in overlaps:
        split = make_vfl_partition(x, y, overlap_size=n_o, seed=seed + 1,
                                   num_classes=num_classes)
        mk = lambda: [make_cnn_extractor(rep_dim=64, widths=(8, 16),
                                         blocks_per_stage=1) for _ in range(2)]
        ssl = [SSLConfig(modality="image", max_shift=2, cutout_size=4,
                         confidence_threshold=0.6)] * 2
        pcfg = ProtocolConfig(client_epochs=epochs, server_epochs=min(3 * epochs, 60),
                              client_lr=0.02)
        icfg = IterativeConfig(iterations=iters)

        methods = {
            "vanilla": lambda: run_vanilla(jax.random.PRNGKey(2), split, mk(), ssl, icfg),
            "fedcvt": lambda: run_fedcvt(jax.random.PRNGKey(2), split, mk(), ssl, icfg),
            "fedbcd": lambda: run_fedbcd(jax.random.PRNGKey(2), split, mk(), ssl, icfg),
            "one_shot": lambda: run_one_shot(jax.random.PRNGKey(2), split, mk(), ssl, pcfg),
            "few_shot": lambda: run_few_shot(jax.random.PRNGKey(2), split, mk(), ssl, pcfg),
        }
        for name, fn in methods.items():
            t0 = time.time()
            res = fn()
            rows.append({
                "overlap": n_o, "method": name,
                "metric": res.metric,
                "comm_times": res.ledger.comm_times(),
                "comm_mb": res.ledger.total_megabytes(),
                "wall_s": time.time() - t0,
            })
            print(f"overlap={n_o:5d} {name:10s} acc={res.metric:.4f} "
                  f"times={rows[-1]['comm_times']:6d} "
                  f"mb={rows[-1]['comm_mb']:8.2f} ({rows[-1]['wall_s']:.0f}s)",
                  flush=True)
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--fast", action="store_true")
    args = ap.parse_args()
    if args.full:
        rows = run([256, 512, 1024, 2048], 12000, 8000, 120, image_size=32,
                   num_classes=10)
    elif args.fast:
        rows = run([48], 800, 60, 8, num_classes=4)
    else:
        rows = run([32, 64, 128], 2400, 400, 60, num_classes=6)
    print("\nname,us_per_call,derived")
    for r in rows:
        print(f"table1/{r['method']}/overlap{r['overlap']},"
              f"{r['wall_s'] * 1e6:.0f},"
              f"acc={r['metric']:.4f};comm_mb={r['comm_mb']:.2f};"
              f"comm_times={r['comm_times']}")


if __name__ == "__main__":
    main()
