"""Kernel + engine microbenchmarks.

Kernels: interpret-mode correctness + host-timed oracle comparison across
the hot-spot shapes. On-TPU timing needs real hardware; here ``us_per_call``
is the pure-jnp oracle (the XLA-fused baseline the Pallas kernel must beat
on TPU), and ``derived`` records kernel/oracle max-abs error.

Engine: end-to-end wall time of one multi-client local-SSL session on the
vmap-over-clients jitted fast path vs the per-client Python loop (both
including trace/compile, i.e. what a protocol run actually pays) — the
jitted path must win."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def bench_engine() -> None:
    """One homogeneous 4-party SSL session: vmap fast path vs Python loop."""
    from repro import engine
    from repro.core.ssl import SSLConfig
    from repro.models.extractors import make_classifier, make_mlp_extractor

    parties, n_l, n_u, feat = 4, 256, 1024, 32
    ext = make_mlp_extractor(rep_dim=16, hidden=(64,))
    head = make_classifier(2)
    ssl_cfg = SSLConfig(modality="tabular")
    key = jax.random.PRNGKey(0)
    tasks = []
    for k in range(parties):
        kp, kl, ku, ky = jax.random.split(jax.random.fold_in(key, k), 4)
        x_l = jax.random.normal(kl, (n_l, feat))
        x_u = jax.random.normal(ku, (n_u, feat))
        y = jax.random.randint(ky, (n_l,), 0, 2)
        params = engine.PartyParams(ext.init(kp, x_l[:2]),
                                    head.init(kp, jnp.zeros((1, 16))))
        tasks.append(engine.PartyTask(ext, head, params, ssl_cfg, x_l, y, x_u,
                                      feature_mean=jnp.mean(x_u, axis=0)))
    hp = engine.SSLHParams(epochs=3, batch_size=32)

    def run(mode):
        t0 = time.time()
        params, _, vmapped = engine.train_clients_ssl(
            jax.random.PRNGKey(1), tasks, hp, mode=mode)
        jax.block_until_ready(jax.tree_util.tree_leaves(params))
        return (time.time() - t0) * 1e6, vmapped

    us_python, _ = run("python")
    us_vmap, vmapped = run("vmap")
    assert vmapped
    print(f"engine/ssl_python_loop/K{parties}e{hp.epochs},{us_python:.0f},")
    print(f"engine/ssl_vmap_jit/K{parties}e{hp.epochs},{us_vmap:.0f},"
          f"speedup={us_python / us_vmap:.2f}x")


def main() -> None:
    print("name,us_per_call,derived")
    bench_engine()

    # kmeans assignment: the paper's step-③ shape (N_o grads × C classes)
    from repro.kernels.kmeans import ops as km_ops, ref as km_ref
    for (n, d, c) in [(2048, 128, 10), (4096, 256, 100)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        cen = jax.random.normal(jax.random.PRNGKey(1), (c, d))
        ref_fn = jax.jit(km_ref.kmeans_assign)
        us = _time(ref_fn, x, cen)
        agree = float(jnp.mean(km_ops.kmeans_assign(x, cen) == ref_fn(x, cen)))
        print(f"kernel/kmeans/{n}x{d}x{c},{us:.1f},agree={agree:.4f}")

    # SDPA estimator: the few-shot server shape (N_u >> N_o)
    from repro.kernels.sdpa_estimator import ops as sd_ops, ref as sd_ref
    for (nu, no, d) in [(4096, 256, 128), (8192, 512, 128)]:
        hu = jax.random.normal(jax.random.PRNGKey(0), (nu, d))
        hoa = jax.random.normal(jax.random.PRNGKey(1), (no, d))
        hob = jax.random.normal(jax.random.PRNGKey(2), (no, d))
        ref_fn = jax.jit(sd_ref.sdpa_estimate)
        us = _time(ref_fn, hu, hoa, hob)
        err = float(jnp.max(jnp.abs(sd_ops.sdpa_estimate(hu, hoa, hob)
                                    - ref_fn(hu, hoa, hob))))
        print(f"kernel/sdpa/{nu}x{no}x{d},{us:.1f},maxerr={err:.2e}")

    # fused rmsnorm: per-layer shape of the biggest assigned arch
    from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
    for (rows, d) in [(4096, 1024), (2048, 4096)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (rows, d))
        s = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d,))
        ref_fn = jax.jit(rn_ref.rms_norm)
        us = _time(ref_fn, x, s)
        err = float(jnp.max(jnp.abs(rn_ops.rms_norm(x, s) - ref_fn(x, s))))
        print(f"kernel/rmsnorm/{rows}x{d},{us:.1f},maxerr={err:.2e}")

    # decode attention: serving shape
    from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
    for (b, h, hkv, s, dh) in [(8, 32, 8, 2048, 128)]:
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, dh))
        kc = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, dh))
        vc = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, dh))
        ref_fn = jax.jit(da_ref.decode_attention)
        us = _time(ref_fn, q, kc, vc)
        err = float(jnp.max(jnp.abs(da_ops.decode_attention(q, kc, vc)
                                    - ref_fn(q, kc, vc))))
        print(f"kernel/decode_attn/b{b}h{h}s{s},{us:.1f},maxerr={err:.2e}")


if __name__ == "__main__":
    main()
