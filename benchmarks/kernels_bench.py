"""Kernel + engine microbenchmarks.

Kernels: interpret-mode correctness + host-timed oracle comparison across
the hot-spot shapes. On-TPU timing needs real hardware; here ``us_per_call``
is the pure-jnp oracle (the XLA-fused baseline the Pallas kernel must beat
on TPU), and ``derived`` records kernel/oracle max-abs error.

Batched grids (DESIGN.md §15): for the two protocol hot-spots (k-means
assignment, SDPA estimation) the fold-native entries are timed three ways —
ONE ``(B, …)`` grid launch vs ``jax.vmap`` of the XLA reference vs B
sequential single-instance kernel launches — so the crossover the ops.py
headers cite is measured, not asserted. Under CPU interpret mode the
absolute kernel numbers are interpretation overhead; the launch-count
ratio (one dispatch vs B dispatches) is the portable signal.

Engine: end-to-end wall time of one multi-client local-SSL session on the
vmap-over-clients jitted fast path vs the per-client Python loop (both
including trace/compile, i.e. what a protocol run actually pays) — the
jitted path must win.

``--out BENCH_kernels.json`` records every row (name, us_per_call, derived)
plus backend/interpret context as a JSON blob.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters=3):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / iters * 1e6


def _emit(rows, name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")
    rows.append({"name": name, "us_per_call": round(us, 1),
                 "derived": derived})


def bench_engine(rows) -> None:
    """One homogeneous 4-party SSL session: vmap fast path vs Python loop."""
    from repro import engine
    from repro.core.ssl import SSLConfig
    from repro.models.extractors import make_classifier, make_mlp_extractor

    parties, n_l, n_u, feat = 4, 256, 1024, 32
    ext = make_mlp_extractor(rep_dim=16, hidden=(64,))
    head = make_classifier(2)
    ssl_cfg = SSLConfig(modality="tabular")
    key = jax.random.PRNGKey(0)
    tasks = []
    for k in range(parties):
        kp, kl, ku, ky = jax.random.split(jax.random.fold_in(key, k), 4)
        x_l = jax.random.normal(kl, (n_l, feat))
        x_u = jax.random.normal(ku, (n_u, feat))
        y = jax.random.randint(ky, (n_l,), 0, 2)
        params = engine.PartyParams(ext.init(kp, x_l[:2]),
                                    head.init(kp, jnp.zeros((1, 16))))
        tasks.append(engine.PartyTask(ext, head, params, ssl_cfg, x_l, y, x_u,
                                      feature_mean=jnp.mean(x_u, axis=0)))
    hp = engine.SSLHParams(epochs=3, batch_size=32)

    def run(mode):
        t0 = time.time()
        params, _, vmapped = engine.train_clients_ssl(
            jax.random.PRNGKey(1), tasks, hp, mode=mode)
        jax.block_until_ready(jax.tree_util.tree_leaves(params))
        return (time.time() - t0) * 1e6, vmapped

    us_python, _ = run("python")
    us_vmap, vmapped = run("vmap")
    assert vmapped
    _emit(rows, f"engine/ssl_python_loop/K{parties}e{hp.epochs}", us_python)
    _emit(rows, f"engine/ssl_vmap_jit/K{parties}e{hp.epochs}", us_vmap,
          f"speedup={us_python / us_vmap:.2f}x")


def bench_batched_grids(rows) -> None:
    """The fold-native batched entries, three routes per kernel:

    - ``grid``  — ONE (B, …) Pallas grid launch (the fold-native entry)
    - ``vmap``  — jax.vmap of the pure-jnp XLA reference (the baseline the
                  kernel must beat on TPU)
    - ``seq``   — B sequential width-1 kernel launches (what the retired
                  per-entry fallback used to pay: B dispatches + B pads)

    Shapes are the ones the ops.py headers cite (B=8 ≙ a 2-seed × 1-group ×
    4-party stacked fold). Agreement is gated here, not just recorded: the
    batched grid must be bit-equal to the reference for k-means and ≤1e-5
    for SDPA.
    """
    from repro.kernels.kmeans import ops as km_ops, ref as km_ref
    from repro.kernels.sdpa_estimator import ops as sd_ops, ref as sd_ref

    # k-means assignment, step-③ fold shape
    b, n, d, c = 8, 2048, 128, 10
    x = jax.random.normal(jax.random.PRNGKey(0), (b, n, d))
    cen = jax.random.normal(jax.random.PRNGKey(1), (b, c, d))
    grid_fn = jax.jit(km_ops.kmeans_assign_batched)
    vmap_fn = jax.jit(jax.vmap(km_ref.kmeans_assign))

    def km_seq(x, cen):
        return [km_ops.kmeans_assign(x[i], cen[i]) for i in range(b)]

    shape = f"B{b}x{n}x{d}x{c}"
    us_grid = _time(grid_fn, x, cen)
    us_vmap = _time(vmap_fn, x, cen)
    us_seq = _time(km_seq, x, cen)
    agree = float(jnp.mean(grid_fn(x, cen) == vmap_fn(x, cen)))
    assert agree == 1.0, f"batched k-means grid != vmapped oracle ({agree})"
    _emit(rows, f"kernel/kmeans_batched_grid/{shape}", us_grid,
          f"agree={agree:.4f}")
    _emit(rows, f"kernel/kmeans_batched_vmap_ref/{shape}", us_vmap,
          f"grid_vs_vmap={us_vmap / us_grid:.2f}x")
    _emit(rows, f"kernel/kmeans_batched_seq_launch/{shape}", us_seq,
          f"grid_vs_seq={us_seq / us_grid:.2f}x")

    # SDPA estimation, few-shot ③' fold shape
    b, nu, no, d = 8, 4096, 256, 128
    hu = jax.random.normal(jax.random.PRNGKey(0), (b, nu, d))
    hoa = jax.random.normal(jax.random.PRNGKey(1), (b, no, d))
    hob = jax.random.normal(jax.random.PRNGKey(2), (b, no, d))
    grid_fn = jax.jit(sd_ops.sdpa_estimate_batched)
    vmap_fn = jax.jit(jax.vmap(sd_ref.sdpa_estimate))

    def sd_seq(hu, hoa, hob):
        return [sd_ops.sdpa_estimate(hu[i], hoa[i], hob[i]) for i in range(b)]

    shape = f"B{b}x{nu}x{no}x{d}"
    us_grid = _time(grid_fn, hu, hoa, hob)
    us_vmap = _time(vmap_fn, hu, hoa, hob)
    us_seq = _time(sd_seq, hu, hoa, hob)
    err = float(jnp.max(jnp.abs(grid_fn(hu, hoa, hob)
                                - vmap_fn(hu, hoa, hob))))
    assert err <= 1e-5, f"batched SDPA grid off the oracle by {err:.2e}"
    _emit(rows, f"kernel/sdpa_batched_grid/{shape}", us_grid,
          f"maxerr={err:.2e}")
    _emit(rows, f"kernel/sdpa_batched_vmap_ref/{shape}", us_vmap,
          f"grid_vs_vmap={us_vmap / us_grid:.2f}x")
    _emit(rows, f"kernel/sdpa_batched_seq_launch/{shape}", us_seq,
          f"grid_vs_seq={us_seq / us_grid:.2f}x")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default=None,
                    help="write all rows as a JSON blob to this path")
    args = ap.parse_args(argv)

    rows: list = []
    print("name,us_per_call,derived")
    bench_engine(rows)

    # kmeans assignment: the paper's step-③ shape (N_o grads × C classes)
    from repro.kernels.kmeans import ops as km_ops, ref as km_ref
    for (n, d, c) in [(2048, 128, 10), (4096, 256, 100)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (n, d))
        cen = jax.random.normal(jax.random.PRNGKey(1), (c, d))
        ref_fn = jax.jit(km_ref.kmeans_assign)
        us = _time(ref_fn, x, cen)
        agree = float(jnp.mean(km_ops.kmeans_assign(x, cen) == ref_fn(x, cen)))
        _emit(rows, f"kernel/kmeans/{n}x{d}x{c}", us, f"agree={agree:.4f}")

    # SDPA estimator: the few-shot server shape (N_u >> N_o)
    from repro.kernels.sdpa_estimator import ops as sd_ops, ref as sd_ref
    for (nu, no, d) in [(4096, 256, 128), (8192, 512, 128)]:
        hu = jax.random.normal(jax.random.PRNGKey(0), (nu, d))
        hoa = jax.random.normal(jax.random.PRNGKey(1), (no, d))
        hob = jax.random.normal(jax.random.PRNGKey(2), (no, d))
        ref_fn = jax.jit(sd_ref.sdpa_estimate)
        us = _time(ref_fn, hu, hoa, hob)
        err = float(jnp.max(jnp.abs(sd_ops.sdpa_estimate(hu, hoa, hob)
                                    - ref_fn(hu, hoa, hob))))
        _emit(rows, f"kernel/sdpa/{nu}x{no}x{d}", us, f"maxerr={err:.2e}")

    # the fold-native batched grids (DESIGN.md §15)
    bench_batched_grids(rows)

    # fused rmsnorm: per-layer shape of the biggest assigned arch
    from repro.kernels.rmsnorm import ops as rn_ops, ref as rn_ref
    for (rows_, d) in [(4096, 1024), (2048, 4096)]:
        x = jax.random.normal(jax.random.PRNGKey(0), (rows_, d))
        s = 1.0 + 0.1 * jax.random.normal(jax.random.PRNGKey(1), (d,))
        ref_fn = jax.jit(rn_ref.rms_norm)
        us = _time(ref_fn, x, s)
        err = float(jnp.max(jnp.abs(rn_ops.rms_norm(x, s) - ref_fn(x, s))))
        _emit(rows, f"kernel/rmsnorm/{rows_}x{d}", us, f"maxerr={err:.2e}")

    # decode attention: serving shape
    from repro.kernels.decode_attention import ops as da_ops, ref as da_ref
    for (b, h, hkv, s, dh) in [(8, 32, 8, 2048, 128)]:
        q = jax.random.normal(jax.random.PRNGKey(0), (b, h, dh))
        kc = jax.random.normal(jax.random.PRNGKey(1), (b, hkv, s, dh))
        vc = jax.random.normal(jax.random.PRNGKey(2), (b, hkv, s, dh))
        ref_fn = jax.jit(da_ref.decode_attention)
        us = _time(ref_fn, q, kc, vc)
        err = float(jnp.max(jnp.abs(da_ops.decode_attention(q, kc, vc)
                                    - ref_fn(q, kc, vc))))
        _emit(rows, f"kernel/decode_attn/b{b}h{h}s{s}", us, f"maxerr={err:.2e}")

    if args.out:
        from repro.kernels import interpret_mode
        blob = {"backend": jax.default_backend(),
                "interpret": interpret_mode(),
                "rows": rows}
        with open(args.out, "w") as f:
            json.dump(blob, f, indent=2)
        print(f"wrote {len(rows)} rows to {args.out}")


if __name__ == "__main__":
    main()
