"""Scenario-group partitioner for the scenario-axis fold (DESIGN.md §12).

The seed-batched runners (DESIGN.md §10-11) treat the batch axis as
*anonymous*: nothing in the stacked programs knows an entry is "seed s" —
so any set of (scenario, seed) pairs whose entries share one shape and one
party semantics can ride the same axis. This module decides which catalog
entries may share it.

Two scenarios are *stackable* when, party position by party position, the
engine's own vmap precondition (:func:`repro.engine.parties_are_homogeneous`
— apply-fn identity + equal rep_dim + equal SSLConfig + equal feature
dims) holds across the pair, AND their built splits share every shape and
the class count, AND their training budgets match (the frontier compiles
one config per group). Note the *within*-scenario predicate is NOT
required: a party-heterogeneous scenario like the (10, 13)-feature credit
family folds across scenarios at the orchestration level — each flat entry
still takes its own engine path inside the fold.

``fold_signature`` is the hashable image of that relation; ``partition``
buckets signatures deterministically (first-occurrence order, ``None``
signatures become singletons); ``group_scenarios`` combines the two and
re-verifies every multi-member bucket with the engine predicate itself, so
a signature collision can only ever split a group, never merge a wrong
one.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Hashable, List, Optional, Sequence, Tuple

from repro.engine import parties_are_homogeneous, sessions
from repro.scenarios.registry import ScenarioBundle, ScenarioSpec


def split_signature(split) -> tuple:
    """Full shape signature of a built vertical split — the stacking
    precondition on the data side (matches the one ``run_seeds`` checks)."""
    mask = getattr(split, "aligned_mask", None)
    return (tuple(x.shape for x in split.aligned),
            tuple(x.shape for x in split.unaligned),
            tuple(x.shape for x in split.test_aligned),
            split.labels.shape, split.test_labels.shape, split.num_classes,
            # masked (equal-shape capacity) and unmasked splits never stack:
            # the mask changes the SSL loss structure even at equal shapes
            None if mask is None else tuple(mask.shape))


def _closure_key(fn) -> tuple:
    """Code-object + hashable-closure-cell identity of a function — the
    same discipline as ``sessions.model_key``, applied here to ``init``
    factories (un-hashable cells get a fresh token: conservative
    singleton, never a wrong merge)."""
    cells = []
    for c in (getattr(fn, "__closure__", None) or ()):
        v = c.cell_contents
        try:
            hash(v)
            cells.append(v)
        except TypeError:
            cells.append(object())
    return (getattr(fn, "__code__", None), tuple(cells))


def _init_fns_match(a, b) -> bool:
    """True when two param-init factories provably agree (same function,
    or same code with equal captured closure values) — the widths an
    ``apply`` generic over its params dict doesn't expose live here."""
    fa, fb = a.init, b.init
    if fa is fb:
        return True
    if getattr(fa, "__code__", None) is not getattr(fb, "__code__", False):
        return False
    try:
        return bool(
            [c.cell_contents for c in (fa.__closure__ or ())]
            == [c.cell_contents for c in (fb.__closure__ or ())])
    except Exception:
        return False


def fold_signature(spec: ScenarioSpec,
                   bundle: ScenarioBundle) -> Optional[Hashable]:
    """Hashable stack key of one built scenario: equal signatures ⇒ the
    entries may share one folded batch axis. Party-wise ``model_key`` is
    the hashable proxy for the engine's apply-fn identity (equal keys ⇒
    ``_apply_fns_match``), and the ``init`` factory's closure key carries
    the architecture widths a params-generic ``apply`` doesn't expose —
    the fold stacks *parameter carries*, so the shapes ``init`` produces
    must agree too. Un-digestable closures get fresh tokens (conservative
    singleton). Returns ``None`` when the key isn't hashable — those
    entries never group."""
    sig = (
        tuple((sessions.model_key(ext), _closure_key(ext.init), cfg)
              for ext, cfg in zip(bundle.extractors, bundle.ssl_cfgs)),
        split_signature(bundle.split),
        spec.budgets,
        spec.fewshot_threshold,
    )
    try:
        hash(sig)
    except TypeError:
        return None
    return sig


def partition(signatures: Sequence[Optional[Hashable]]) -> List[List[int]]:
    """Deterministic order-preserving partition of indices by signature:
    groups appear in first-occurrence order, members keep input order, and
    a ``None`` signature always falls out as its own singleton."""
    groups: List[List[int]] = []
    by_sig: dict = {}
    for i, sig in enumerate(signatures):
        if sig is None:
            groups.append([i])
            continue
        bucket = by_sig.get(sig)
        if bucket is None:
            bucket = []
            by_sig[sig] = bucket
            groups.append(bucket)
        bucket.append(i)
    return groups


def bundles_fold_compatible(a: ScenarioBundle, b: ScenarioBundle) -> bool:
    """The engine predicate applied *across* two scenarios, party position
    by party position — ground truth behind :func:`fold_signature`."""
    if len(a.extractors) != len(b.extractors):
        return False
    if split_signature(a.split) != split_signature(b.split):
        return False
    return all(
        parties_are_homogeneous(
            [ea, eb], [ca, cb],
            [xa.shape, xb.shape])
        and _init_fns_match(ea, eb)
        for ea, eb, ca, cb, xa, xb in zip(
            a.extractors, b.extractors, a.ssl_cfgs, b.ssl_cfgs,
            a.split.aligned, b.split.aligned))


@dataclass
class ScenarioGroup:
    """One stackable bucket of catalog entries (indices into the input
    entry list, in input order). ``size == 1`` is the width-1 case — it
    runs through the very same folded path."""

    indices: List[int]
    names: List[str]
    signature: Optional[Hashable]

    @property
    def size(self) -> int:
        return len(self.indices)


def group_scenarios(
    entries: Sequence[Tuple[ScenarioSpec, ScenarioBundle]],
) -> List[ScenarioGroup]:
    """Partition built scenarios into stackable groups.

    Buckets by :func:`fold_signature`, then re-verifies every multi-member
    bucket against its first member with :func:`bundles_fold_compatible`
    (the engine predicate itself); an entry that fails verification is
    demoted to a singleton appended after its would-be group, so a
    signature bug can only cost fold width, never correctness.
    """
    sigs = [fold_signature(spec, bundle) for spec, bundle in entries]
    groups: List[ScenarioGroup] = []
    for idxs in partition(sigs):
        head = entries[idxs[0]][1]
        kept = [i for i in idxs
                if i == idxs[0] or bundles_fold_compatible(entries[i][1], head)]
        demoted = [i for i in idxs if i not in kept]
        for members, sig in ([(kept, sigs[idxs[0]])]
                             + [([i], None) for i in demoted]):
            groups.append(ScenarioGroup(
                indices=list(members),
                names=[entries[i][0].name for i in members],
                signature=sig))
    return groups
