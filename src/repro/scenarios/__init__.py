"""Scenario registry + catalog: named, parameterised VFL problem instances.

Importing this package registers the full catalog. See DESIGN.md §8.
"""
from repro.scenarios.faults import FaultSpec
from repro.scenarios.registry import (
    GENERATORS,
    ScenarioBundle,
    ScenarioSpec,
    build,
    by_tag,
    get,
    names,
    register,
)
from repro.scenarios import catalog  # noqa: F401  (registers the catalog)
from repro.scenarios.grouping import (
    ScenarioGroup,
    fold_signature,
    group_scenarios,
)

__all__ = [
    "FaultSpec",
    "GENERATORS",
    "ScenarioBundle",
    "ScenarioGroup",
    "ScenarioSpec",
    "build",
    "by_tag",
    "fold_signature",
    "get",
    "group_scenarios",
    "names",
    "register",
]
