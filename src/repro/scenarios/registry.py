"""Declarative scenario registry (DESIGN.md §8).

A *scenario* is a named, fully parameterised VFL problem instance: which
synthetic generator to draw from (and with which knobs), how many parties
hold which feature blocks, how many rows overlap, which extractor
architecture each party trains, and which SSL recipe the local sessions
use. Scenarios are what the benchmark frontier sweeps over and what tests
pin — one string names the whole experimental condition:

    from repro import scenarios

    bundle = scenarios.build("hard/overlap-32", seed=0)
    res = run_one_shot(key, bundle.split, bundle.extractors,
                       bundle.ssl_cfgs, ProtocolConfig(...))

Specs are frozen dataclasses (hashable, reproducible from their fields
alone); ``spec.smoke()`` returns a shrunken copy of the same condition for
CI-speed runs. The catalog of registered scenarios lives in
``repro.scenarios.catalog`` and covers the axes the paper's evaluation
varies: overlap size 32→2048, feature skew, label noise, 2→8 parties,
tabular + image-strip + image-patch modalities, and the hardened
limited-overlap task on which iterative VFL cannot fit the overlap.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.core.ssl import SSLConfig
from repro.data import synthetic, vertical
from repro.models.extractors import Model
from repro.scenarios.faults import FaultSpec

GENERATORS: Dict[str, Callable] = {
    "tabular_credit": synthetic.make_tabular_credit,
    "cluster_tabular": synthetic.make_cluster_tabular,
    "image_classification": synthetic.make_image_classification,
}


@dataclass(frozen=True)
class ScenarioSpec:
    """One named experimental condition. All fields are hashable values so a
    spec round-trips through ``dataclasses.replace`` and dict keys."""

    name: str
    modality: str                 # "tabular" | "image"
    generator: str                # key into GENERATORS
    overlap: int                  # N_o
    num_samples: int
    #: fixed aligned-block capacity for the equal-shape overlap family
    #: (DESIGN.md §14): the split always materializes this many aligned rows
    #: (real overlap first, cyclic duplicates after, validity mask alongside),
    #: so members with different N_o share one shape signature and stack.
    overlap_capacity: Optional[int] = None
    num_parties: int = 2
    gen_params: Tuple[Tuple[str, Any], ...] = ()
    feature_sizes: Optional[Tuple[int, ...]] = None   # tabular block sizes
    image_grid: Optional[Tuple[int, int]] = None      # (rows, cols) patches
    rep_dim: int = 16
    hidden: Tuple[int, ...] = (64,)                   # MLP extractor widths
    widths: Tuple[int, ...] = (8, 16)                 # CNN stage widths
    blocks_per_stage: int = 1
    ssl_params: Tuple[Tuple[str, Any], ...] = ()
    fewshot_threshold: Optional[float] = None         # Eq. 9 gate t (None = default)
    #: injected party fault (DESIGN.md §16). Pure data the runners thread
    #: through as per-entry arguments — deliberately EXCLUDED from
    #: ``grouping.fold_signature`` so a mixed-fault family still stacks.
    fault: Optional[FaultSpec] = None
    budgets: Tuple[Tuple[str, int], ...] = ()         # training-budget hints
    tags: Tuple[str, ...] = ()
    smoke_overlap: int = 32
    smoke_samples: int = 2000
    description: str = ""

    def budget(self, key: str, default: int) -> int:
        """Per-scenario training-budget hint (epochs/iterations), with a
        caller-supplied default."""
        return dict(self.budgets).get(key, default)

    def smoke(self) -> "ScenarioSpec":
        """CI-speed variant of the same condition: capped overlap and sample
        count, identical generator/architecture/SSL parameters. The
        equal-shape capacity shrinks with the overlap cap so the family's
        members still share one (smaller) padded shape."""
        capacity = self.overlap_capacity
        if capacity is not None:
            capacity = min(capacity, self.smoke_overlap)
        return replace(self,
                       overlap=min(self.overlap, self.smoke_overlap),
                       num_samples=min(self.num_samples, self.smoke_samples),
                       overlap_capacity=capacity)


@dataclass
class ScenarioBundle:
    """A built scenario: the vertical split plus per-party model stacks."""

    spec: ScenarioSpec
    split: vertical.VerticalSplit
    extractors: List[Model]
    ssl_cfgs: List[SSLConfig]


_REGISTRY: Dict[str, ScenarioSpec] = {}


def register(spec: ScenarioSpec) -> ScenarioSpec:
    if spec.name in _REGISTRY:
        raise ValueError(f"scenario {spec.name!r} already registered")
    if spec.generator not in GENERATORS:
        raise ValueError(f"unknown generator {spec.generator!r}")
    _REGISTRY[spec.name] = spec
    return spec


def get(name: str) -> ScenarioSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        known = ", ".join(sorted(_REGISTRY))
        raise KeyError(f"unknown scenario {name!r}; registered: {known}")


def names() -> List[str]:
    return sorted(_REGISTRY)


def by_tag(tag: str) -> List[ScenarioSpec]:
    return [_REGISTRY[n] for n in sorted(_REGISTRY)
            if tag in _REGISTRY[n].tags]


def _make_extractors(spec: ScenarioSpec) -> List[Model]:
    # single-sourced with the deployment artifact: the per-party specs a
    # scenario implies are written down ONCE (checkpoint/artifact.py), so
    # a trained result's exported apply identity is exactly what built it
    from repro.checkpoint.artifact import extractor_specs_for

    return [s.build() for s in extractor_specs_for(spec)]


def _make_ssl_cfgs(spec: ScenarioSpec) -> List[SSLConfig]:
    params = dict(spec.ssl_params)
    if spec.modality == "image":
        cfg = SSLConfig(modality="image", **params)
    else:
        cfg = SSLConfig(modality="tabular", **params)
    return [cfg] * spec.num_parties


def build(name_or_spec, seed: int = 0, smoke: bool = False) -> ScenarioBundle:
    """Materialize a scenario: draw the synthetic dataset, partition it
    vertically, and construct the per-party extractor/SSL stacks."""
    spec = (name_or_spec if isinstance(name_or_spec, ScenarioSpec)
            else get(name_or_spec))
    if smoke:
        spec = spec.smoke()
    gen = GENERATORS[spec.generator]
    x, y = gen(jax.random.PRNGKey(1000 + seed), spec.num_samples,
               **dict(spec.gen_params))
    num_classes = int(y.max()) + 1
    split = vertical.make_vfl_partition(
        x, y, overlap_size=spec.overlap, num_parties=spec.num_parties,
        feature_sizes=spec.feature_sizes, seed=seed,
        num_classes=num_classes, image_grid=spec.image_grid,
        overlap_capacity=spec.overlap_capacity)
    return ScenarioBundle(spec=spec, split=split,
                          extractors=_make_extractors(spec),
                          ssl_cfgs=_make_ssl_cfgs(spec))
