"""The registered scenario catalog — the axes the paper's evaluation varies.

Families:

* ``credit/overlap-N``  — overlap-size sweep 32 → 2048 on the UCI-credit-like
  tabular task (Fig. 6/7's x-axis).
* ``credit/feature-skew`` — party A holds 18 of 23 features, party B only 5
  (information-skewed parties).
* ``credit/label-noise``  — 25% label flips on the server's labels.
* ``credit/parties-K``    — 4- and 8-party tabular splits (the paper's K=2
  protocol is K-ary; see test_protocol_k3_parties).
* ``hard/overlap-N``      — the hardened limited-overlap task
  (``make_cluster_tabular``): wide Gaussian clusters, half the feature
  dimensions nuisance noise, 15% label flips. A supervised fit of the tiny
  overlap places boundaries from 1-3 noisy points per cluster while local
  SSL sees thousands of pool rows — the regime where one-shot VFL beats
  iterative VFL outright (the un-xfail'd headline test and the bench
  frontier's smoke gate both pin it).
* ``hard/overlap-N-eq``   — equal-shape variants of the hard family: the
  aligned block is padded to a fixed 64-row capacity with cyclic duplicates
  under a validity mask, so different-N_o members share one shape signature
  and stack into a single scenario-folded group (DESIGN.md §14).
* ``image/halves`` and ``image/patch-4`` — image modality split into
  vertical strips (paper §5.1) or a 2×2 patch grid (4 parties).
* ``fault/*``             — the fault-injection family (DESIGN.md §16): one
  4-party tabular condition replicated under party-1 dropout at each of the
  four named protocol stages, a half-budget straggler, DP-noised uploads at
  two σ, and an APC-style representation-only party — plus the fault-free
  twin ``fault/none`` the gate measures degradation deltas against. The
  fault rides the spec as pure data (excluded from ``fold_signature``), so
  the whole family folds into ONE stacked S×C×K group.
"""
from __future__ import annotations

from repro.scenarios.faults import FaultSpec
from repro.scenarios.registry import ScenarioSpec, register

OVERLAP_SWEEP = (32, 64, 128, 256, 512, 1024, 2048)

for _n_o in OVERLAP_SWEEP:
    register(ScenarioSpec(
        name=f"credit/overlap-{_n_o}",
        modality="tabular",
        generator="tabular_credit",
        overlap=_n_o,
        num_samples=max(1500, 3 * _n_o),
        feature_sizes=(10, 13),
        rep_dim=16,
        budgets=(("client_epochs", 8), ("server_epochs", 30),
                 ("iterations", 400)),
        tags=("sweep", "tabular") + (("frontier",) if _n_o in (128, 512)
                                     else ()),
        description=f"UCI-credit-like tabular VFL, N_o={_n_o}",
    ))

register(ScenarioSpec(
    name="credit/feature-skew",
    modality="tabular",
    generator="tabular_credit",
    overlap=128,
    num_samples=1500,
    feature_sizes=(18, 5),
    rep_dim=16,
    budgets=(("client_epochs", 8), ("server_epochs", 30),
             ("iterations", 400)),
    tags=("skew", "tabular"),
    description="information-skewed parties: 18 vs 5 of 23 features",
))

register(ScenarioSpec(
    name="credit/label-noise",
    modality="tabular",
    generator="tabular_credit",
    overlap=128,
    num_samples=1500,
    gen_params=(("label_noise", 0.25),),
    feature_sizes=(10, 13),
    rep_dim=16,
    budgets=(("client_epochs", 8), ("server_epochs", 30),
             ("iterations", 400)),
    tags=("noise", "tabular"),
    description="25% label flips on the server's overlap labels",
))

for _k, _d in ((4, 32), (8, 40)):
    register(ScenarioSpec(
        name=f"credit/parties-{_k}",
        modality="tabular",
        generator="tabular_credit",
        overlap=128,
        num_samples=1800,
        num_parties=_k,
        gen_params=(("num_features", _d),),
        rep_dim=8,
        hidden=(32,),
        budgets=(("client_epochs", 8), ("server_epochs", 30),
                 ("iterations", 400)),
        tags=("parties", "tabular"),
        description=f"{_k}-party tabular split, {_d} features evenly",
    ))

for _n_o in (32, 64):
    register(ScenarioSpec(
        name=f"hard/overlap-{_n_o}",
        modality="tabular",
        generator="cluster_tabular",
        overlap=_n_o,
        num_samples=3000,
        gen_params=(("num_informative", 24), ("num_nuisance", 16),
                    ("num_clusters", 12), ("cluster_std", 0.3),
                    ("nuisance_std", 2.0), ("label_noise", 0.15)),
        feature_sizes=(20, 20),
        rep_dim=16,
        ssl_params=(("confidence_threshold", 0.8),),
        budgets=(("client_epochs", 80), ("server_epochs", 40),
                 ("iterations", 400)),
        tags=("hard", "tabular", "frontier", "smoke"),
        smoke_samples=3000,
        smoke_overlap=_n_o,
        description=("hardened limited-overlap task: wide clusters, "
                     "nuisance dims, label flips"),
    ))

for _n_o in (32, 64):
    register(ScenarioSpec(
        # equal-shape variant of the hard family (DESIGN.md §14): the aligned
        # block is always materialized at the family capacity (64 rows — real
        # overlap first, cyclic duplicates after, validity mask alongside) and
        # the first 64 pool rows are reserved regardless of N_o, so BOTH
        # members share one shape signature and literally stack into one
        # scenario-folded group (the grouping test pins the pair)
        name=f"hard/overlap-{_n_o}-eq",
        modality="tabular",
        generator="cluster_tabular",
        overlap=_n_o,
        overlap_capacity=64,
        num_samples=3000,
        gen_params=(("num_informative", 24), ("num_nuisance", 16),
                    ("num_clusters", 12), ("cluster_std", 0.3),
                    ("nuisance_std", 2.0), ("label_noise", 0.15)),
        feature_sizes=(20, 20),
        rep_dim=16,
        ssl_params=(("confidence_threshold", 0.8),),
        budgets=(("client_epochs", 80), ("server_epochs", 40),
                 ("iterations", 400)),
        tags=("hard", "tabular", "eq"),
        smoke_samples=3000,
        smoke_overlap=64,   # == capacity: smoke keeps the padded shape equal
        description=(f"hard task at fixed 64-row aligned capacity, N_o={_n_o} "
                     "real rows + cyclic padding under a validity mask"),
    ))

register(ScenarioSpec(
    # full-overlap edge: every training row is aligned, the per-party
    # private pools are EMPTY — the engine must schedule zero-width
    # unlabeled batches (l_u ≡ 0) instead of NaN-ing the SSL loss
    # (regression scenario for the n_unlabeled == 0 guard)
    name="edge/full-overlap",
    modality="tabular",
    generator="tabular_credit",
    overlap=800,                  # == all non-test rows of 1000 @ 20% test
    num_samples=1000,
    feature_sizes=(10, 13),
    rep_dim=16,
    budgets=(("client_epochs", 4), ("server_epochs", 20),
             ("iterations", 200)),
    tags=("edge", "tabular"),
    smoke_overlap=800,            # smoke() must keep the pools empty
    smoke_samples=1000,
    description="full overlap: N_o = all rows, empty private pools",
))

def _fault_member(suffix: str, fault, description: str) -> ScenarioSpec:
    # ONE experimental condition, nine fault treatments: every member is
    # byte-identical except ``fault``, which fold_signature excludes — the
    # partitioner therefore puts the whole family in one stacked group and
    # the degradation delta vs fault/none is measured inside one program
    return ScenarioSpec(
        name=f"fault/{suffix}",
        modality="tabular",
        generator="cluster_tabular",
        overlap=32,
        num_samples=3000,
        num_parties=4,
        gen_params=(("num_informative", 24), ("num_nuisance", 16),
                    ("num_clusters", 12), ("cluster_std", 0.3),
                    ("nuisance_std", 2.0), ("label_noise", 0.15)),
        feature_sizes=(10, 10, 10, 10),
        rep_dim=16,
        ssl_params=(("confidence_threshold", 0.8),),
        fault=fault,
        budgets=(("client_epochs", 20), ("server_epochs", 30),
                 ("iterations", 200)),
        tags=("fault", "tabular", "frontier"),
        smoke_samples=3000,
        smoke_overlap=32,
        description=description,
    )


register(_fault_member(
    "none", None,
    "fault-free twin of the fault/* family — the degradation baseline"))
for _stage in ("pre-upload", "pre-ssl", "post-ssl", "pre-round2"):
    register(_fault_member(
        f"dropout-{_stage}",
        FaultSpec(kind="dropout", party=1, stage=_stage.replace("-", "_")),
        f"party 1 of 4 drops out {_stage.replace('-', ' ')}: one-shot "
        "reconstructs H_o via Eq. 10, iterative stalls and retries"))
register(_fault_member(
    "straggler-half",
    FaultSpec(kind="straggler", party=1, epoch_fraction=0.5),
    "party 1 completes only half its local SSL epoch budget"))
for _sigma in (0.1, 0.5):
    register(_fault_member(
        f"dp-sigma-{_sigma}",
        FaultSpec(kind="dp_upload", party=1, dp_sigma=_sigma),
        f"party 1 noises every upload at sigma={_sigma}x std "
        "(bytes unchanged — privacy costs accuracy, not communication)"))
register(_fault_member(
    "rep-only",
    FaultSpec(kind="representation_only", party=1),
    "APC-style passive party: contributes representations, never "
    "runs local SSL (frozen extractor)"))


register(ScenarioSpec(
    name="image/halves",
    modality="image",
    generator="image_classification",
    overlap=96,
    num_samples=500,
    gen_params=(("num_classes", 4), ("image_size", 16),
                ("template_strength", 3.0)),
    rep_dim=32,
    widths=(8, 16),
    blocks_per_stage=1,
    ssl_params=(("max_shift", 2), ("cutout_size", 4)),
    budgets=(("client_epochs", 3), ("server_epochs", 10),
             ("iterations", 60)),
    tags=("image",),
    smoke_samples=300,
    smoke_overlap=48,
    description="paper §5.1 layout: images split into vertical halves",
))

register(ScenarioSpec(
    name="image/patch-4",
    modality="image",
    generator="image_classification",
    overlap=96,
    num_samples=500,
    num_parties=4,
    image_grid=(2, 2),
    gen_params=(("num_classes", 4), ("image_size", 16),
                ("template_strength", 3.0)),
    rep_dim=32,
    widths=(8, 16),
    blocks_per_stage=1,
    ssl_params=(("max_shift", 2), ("cutout_size", 4)),
    budgets=(("client_epochs", 3), ("server_epochs", 10),
             ("iterations", 60)),
    tags=("image", "patch"),
    smoke_samples=300,
    smoke_overlap=48,
    description="image-patch modality: 2x2 grid, one quadrant per party",
))
