"""Declarative fault injection for VFL protocol runs (DESIGN.md §16).

A :class:`FaultSpec` attaches to a ``ScenarioSpec`` and describes ONE
party-level fault the protocol must degrade gracefully through:

``dropout``
    the party disappears at a named protocol stage and never returns.
    The one-shot/few-shot server reconstructs its missing H_o^k via the
    paper's Eq. 10 estimator (``core.estimator.sdpa_transform``) from a
    surviving anchor party; the iterative baselines have no estimator,
    so the round loop stalls, is charged retry/timeout comm rounds in
    the ledger, and the session aborts at the drop point.

``straggler``
    the party only completes ``epoch_fraction`` of its local SSL epoch
    budget. Modeled as a per-step validity mask on the fixed-shape SSL
    session (``PartyTask.step_valid``) so the faulted session stays
    stackable — same shapes, same compiled program, mask as data.

``dp_upload``
    every embedding the party uploads is noised with Gaussian noise of
    scale ``dp_sigma * std(upload)`` (VFL Survey arXiv:2405.17495
    §security). Bytes on the wire are unchanged — privacy costs
    accuracy, not communication.

``representation_only``
    APC-style passive party (arXiv:2410.17648): contributes its initial
    representations but never runs local SSL (an all-zero step_valid
    mask — the extractor stays frozen at init).

The spec is pure data: frozen, hashable, and deliberately EXCLUDED from
``scenarios.grouping.fold_signature`` — faults ride the stacked S×C×K
programs as per-entry arguments (masks, noise keys, skip flags), never
as compile-time structure, so a mixed-fault family folds into one group
with zero fresh session-cache entries.
"""
from __future__ import annotations

from dataclasses import dataclass

KINDS = ("dropout", "straggler", "dp_upload", "representation_only")

#: named dropout stages, in protocol order
STAGES = ("pre_upload", "pre_ssl", "post_ssl", "pre_round2")

# Protocol event points, in execution order. A dropout at stage s means
# the party is gone for every event point >= _STAGE_THRESHOLD[s]:
#   POINT_UPLOAD1  step ① overlap-representation upload (+ ② grads down)
#   POINT_SSL      step ④ local SSL (also few-shot ⑤' masked SSL)
#   POINT_UPLOAD2  step ⑤ refreshed-representation upload
#   POINT_ROUND2   every few-shot round-2 event (①' h_u up, ④' probs
#                  down, ⑤' SSL, ⑥' final upload)
#   POINT_EVAL     test-time representation extraction
POINT_UPLOAD1 = 0
POINT_SSL = 1
POINT_UPLOAD2 = 2
POINT_ROUND2 = 3
POINT_EVAL = 4

_STAGE_THRESHOLD = {
    "pre_upload": POINT_UPLOAD1,
    "pre_ssl": POINT_SSL,
    "post_ssl": POINT_UPLOAD2,
    "pre_round2": POINT_ROUND2,
}

#: fraction of the iterative baselines' round loop a dropout at each
#: stage lets complete before the party goes silent (the iterative
#: protocol has no stage structure, so stages map onto loop progress)
ITERATIVE_DROP_FRACTION = {
    "pre_upload": 0.0,
    "pre_ssl": 0.25,
    "post_ssl": 0.5,
    "pre_round2": 0.75,
}


@dataclass(frozen=True)
class FaultSpec:
    """One declarative party fault. Frozen so ``ScenarioSpec`` stays
    hashable; validation happens at construction, not injection time."""

    kind: str
    party: int = 1
    #: dropout only: the named protocol stage the party disappears at
    stage: str = "pre_ssl"
    #: straggler only: fraction of the SSL epoch budget completed
    epoch_fraction: float = 1.0
    #: dp_upload only: noise scale as a multiple of the upload's std
    dp_sigma: float = 0.0
    #: dropout only (iterative baselines): timeout probes the server
    #: sends before abandoning the dropped party
    retry_rounds: int = 3

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"fault kind {self.kind!r} not in {KINDS}")
        if self.party < 0:
            raise ValueError(f"fault party {self.party} must be >= 0")
        if self.kind == "dropout":
            if self.stage not in STAGES:
                raise ValueError(
                    f"dropout stage {self.stage!r} not in {STAGES}")
            if self.retry_rounds < 1:
                raise ValueError(
                    f"retry_rounds {self.retry_rounds} must be >= 1")
        if self.kind == "straggler" \
                and not 0.0 <= self.epoch_fraction <= 1.0:
            raise ValueError(
                f"epoch_fraction {self.epoch_fraction} not in [0, 1]")
        if self.kind == "dp_upload" and self.dp_sigma < 0.0:
            raise ValueError(f"dp_sigma {self.dp_sigma} must be >= 0")

    def drops(self, party: int, point: int) -> bool:
        """Is ``party`` gone at protocol event ``point`` (a POINT_*
        constant)? Only dropout faults ever make a party vanish."""
        return (self.kind == "dropout" and self.party == party
                and _STAGE_THRESHOLD[self.stage] <= point)

    def skips_ssl(self, party: int) -> bool:
        """Does ``party`` run ZERO local SSL steps? True for a dropout
        at/before the SSL point and for representation-only parties."""
        if self.kind == "representation_only" and self.party == party:
            return True
        return self.drops(party, POINT_SSL)

    def parties_survived(self, num_parties: int) -> int:
        """How many parties still participate at eval time. Stragglers,
        DP-noised, and representation-only parties degrade but survive;
        a dropout is gone (any stage threshold <= POINT_EVAL)."""
        return num_parties - 1 if self.kind == "dropout" else num_parties

    def iterative_active_steps(self, iterations: int) -> int:
        """How many round-loop steps the iterative baselines complete
        before a dropout stalls them (``iterations`` when no dropout)."""
        if self.kind != "dropout":
            return iterations
        return int(ITERATIVE_DROP_FRACTION[self.stage] * iterations)
