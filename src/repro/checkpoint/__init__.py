from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint, latest_step
from repro.checkpoint.artifact import (ARTIFACT_VERSION, ExtractorSpec,
                                       TrainedVFLModel, extractor_specs_for,
                                       load_artifact, save_artifact)

__all__ = [
    "save_checkpoint",
    "load_checkpoint",
    "latest_step",
    "ARTIFACT_VERSION",
    "ExtractorSpec",
    "TrainedVFLModel",
    "extractor_specs_for",
    "save_artifact",
    "load_artifact",
]
