from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint, latest_step

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step"]
