"""The trained-VFL deployment artifact (DESIGN.md §13).

The paper's headline is that ~1-2 communication rounds train a *deployable*
joint model; this module is the layer that makes every runner's output an
actual deployment unit. A :class:`TrainedVFLModel` is a typed, versioned
record of everything online serving needs — per-party extractor parameters
plus their *apply identity* (a declarative :class:`ExtractorSpec`, the same
record ``repro.scenarios`` builds party stacks from, so a reloaded artifact
provably reconstructs the trained forward function), the server's joint
classifier head, the source :class:`ScenarioSpec` name and
``ProtocolConfig`` for provenance, and (optionally) the final overlap
representations H_o that few-shot-style missing-party estimation attends
over at inference time (Eq. 10 — *representations*, never raw features, so
the artifact ships exactly what the server already held during training).

Persistence rides on ``checkpoint/ckpt.py``: parameters and overlap reps
are the checkpoint pytree, everything declarative travels in the
JSON metadata entry, and loading rebuilds the template from the specs alone
— no pickles, no code objects on disk.

    art = result.to_artifact(spec, cfg, split=split)     # any VFLResult
    save_artifact("artifacts/hard32", art)
    art2 = load_artifact("artifacts/hard32")
    logits = art2.predict_logits([x_party0, x_party1])   # reference forward

``repro.launch.vfl_serve`` wraps the loaded artifact in a batched fused
forward for continuous traffic; ``predict_logits`` here is the unbatched
reference oracle that serving parity is pinned against.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint
from repro.engine.local_ssl import PartyParams
from repro.models.extractors import (Model, make_classifier,
                                     make_cnn_extractor, make_mlp_extractor)

ARTIFACT_VERSION = 1
_ARTIFACT_STEP = 0          # ckpt step slot: one artifact per directory


@dataclass(frozen=True)
class ExtractorSpec:
    """Declarative apply identity of one party's extractor: the factory and
    the arguments that rebuild the exact forward function. Two equal specs
    build Models whose apply fns share code object and closure values — the
    same guarantee ``engine.sessions.model_key`` keys compiled sessions on."""

    kind: str                              # "mlp" | "cnn"
    rep_dim: int
    hidden: Tuple[int, ...] = ()           # mlp widths
    widths: Tuple[int, ...] = ()           # cnn stage widths
    blocks_per_stage: int = 1              # cnn depth

    def build(self) -> Model:
        if self.kind == "mlp":
            return make_mlp_extractor(rep_dim=self.rep_dim,
                                      hidden=self.hidden)
        if self.kind == "cnn":
            return make_cnn_extractor(rep_dim=self.rep_dim,
                                      widths=self.widths,
                                      blocks_per_stage=self.blocks_per_stage)
        raise ValueError(f"unknown extractor kind {self.kind!r} "
                         f"(artifact from a newer repo version?)")

    def to_meta(self) -> dict:
        return {"kind": self.kind, "rep_dim": self.rep_dim,
                "hidden": list(self.hidden), "widths": list(self.widths),
                "blocks_per_stage": self.blocks_per_stage}

    @staticmethod
    def from_meta(meta: dict) -> "ExtractorSpec":
        return ExtractorSpec(kind=meta["kind"], rep_dim=meta["rep_dim"],
                             hidden=tuple(meta["hidden"]),
                             widths=tuple(meta["widths"]),
                             blocks_per_stage=meta["blocks_per_stage"])


def extractor_specs_for(scenario_spec) -> Tuple[ExtractorSpec, ...]:
    """The per-party extractor specs a :class:`ScenarioSpec` implies — the
    ONE place the scenario→architecture mapping is written down
    (``repro.scenarios.registry`` builds its party stacks from these, so an
    artifact's specs are exactly what trained)."""
    if scenario_spec.modality == "image":
        spec = ExtractorSpec(kind="cnn", rep_dim=scenario_spec.rep_dim,
                             widths=tuple(scenario_spec.widths),
                             blocks_per_stage=scenario_spec.blocks_per_stage)
    else:
        spec = ExtractorSpec(kind="mlp", rep_dim=scenario_spec.rep_dim,
                             hidden=tuple(scenario_spec.hidden))
    return (spec,) * scenario_spec.num_parties


@dataclass
class TrainedVFLModel:
    """A deployable K-party VFL model: the typed serving contract.

    Parameters are live pytrees; everything else is declarative (JSON-safe)
    so ``save_artifact``/``load_artifact`` round-trip through
    ``checkpoint/ckpt.py`` without serializing code."""

    scenario: str                                  # source ScenarioSpec name
    num_classes: int
    feature_shapes: Tuple[Tuple[int, ...], ...]    # per-party trailing shape
    extractor_specs: Tuple[ExtractorSpec, ...]
    client_params: List[PartyParams]               # per-party (extractor, head)
    server_params: Any                             # joint classifier θ_c
    protocol: Dict[str, Any] = field(default_factory=dict)  # ProtocolConfig
    overlap_reps: Optional[List[jnp.ndarray]] = None   # H_o per party (Eq. 10)
    metric_name: str = ""
    metric: float = 0.0
    version: int = ARTIFACT_VERSION

    def __post_init__(self):
        k = len(self.extractor_specs)
        if not (len(self.client_params) == len(self.feature_shapes) == k):
            raise ValueError(
                f"inconsistent party count: {k} extractor specs, "
                f"{len(self.client_params)} param stacks, "
                f"{len(self.feature_shapes)} feature shapes")
        if self.overlap_reps is not None and len(self.overlap_reps) != k:
            raise ValueError("overlap_reps must carry one H_o^k per party")

    # ------------------------------------------------------------- rebuild
    def extractors(self) -> List[Model]:
        return [s.build() for s in self.extractor_specs]

    def classifier(self) -> Model:
        return make_classifier(self.num_classes)

    @property
    def num_parties(self) -> int:
        return len(self.extractor_specs)

    @property
    def parties_are_homogeneous(self) -> bool:
        """True when one stacked forward can serve every party: equal
        extractor specs (⇒ ``_apply_fns_match`` on the rebuilt Models) and
        equal per-party feature shapes — the serving analogue of the
        engine's vmap-fast-path precondition."""
        return (len(set(self.extractor_specs)) == 1
                and len(set(self.feature_shapes)) == 1)

    def protocol_config(self):
        """The training ``ProtocolConfig``, reconstructed from the stored
        fields (deferred import: ``core.protocol`` imports this module)."""
        from repro.core.protocol import ProtocolConfig

        fields = dict(self.protocol)
        if "rep_dtype" in fields:
            fields["rep_dtype"] = jnp.dtype(fields["rep_dtype"])
        return ProtocolConfig(**fields)

    # ----------------------------------------------------------- reference
    def predict_logits(self, xs: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """The unbatched reference forward: per-party extract → concat →
        joint head, identical math to training-time
        ``VFLServer.predict_logits`` — the oracle batched serving parity is
        pinned against (1e-5, tests/test_serving.py)."""
        exts = self.extractors()
        reps = [e.apply(p.extractor, x)
                for e, p, x in zip(exts, self.client_params, xs)]
        return self.classifier().apply(self.server_params,
                                       jnp.concatenate(reps, axis=-1))


def from_state(clients, server, scenario_spec, cfg=None,
               metric_name: str = "", metric: float = 0.0,
               split=None) -> TrainedVFLModel:
    """Build the deployment artifact from trained protocol state (what
    ``VFLResult.to_artifact`` delegates to). ``split`` (optional) supplies
    the aligned rows whose final representations become the artifact's
    ``overlap_reps`` — the keys/values missing-party estimation needs."""
    specs = extractor_specs_for(scenario_spec)
    if len(specs) != len(clients):
        raise ValueError(
            f"scenario {scenario_spec.name!r} declares "
            f"{len(specs)} parties but the result trained {len(clients)}")
    if server.params is None:
        raise ValueError("server has no fitted joint classifier — nothing "
                         "deployable to export")
    protocol_meta: Dict[str, Any] = {}
    if cfg is not None:
        import dataclasses

        for f in dataclasses.fields(cfg):
            v = getattr(cfg, f.name)
            protocol_meta[f.name] = (jnp.dtype(v).name
                                     if f.name == "rep_dtype" else v)
    overlap_reps = None
    feature_shapes = []
    if split is not None:
        overlap_reps = [c.extract(x) for c, x in zip(clients, split.aligned)]
        feature_shapes = [tuple(x.shape[1:]) for x in split.aligned]
    else:
        # fall back to the clients' own parameter geometry: the first MLP
        # weight pins the input width; CNN input shapes need the split
        for spec, c in zip(specs, clients):
            if spec.kind == "mlp":
                feature_shapes.append((c.params.extractor["w0"].shape[0],))
            else:
                raise ValueError("to_artifact needs `split=` for non-MLP "
                                 "parties (feature shapes are not "
                                 "recoverable from the params alone)")
    return TrainedVFLModel(
        scenario=scenario_spec.name,
        num_classes=server.num_classes,
        feature_shapes=tuple(feature_shapes),
        extractor_specs=specs,
        client_params=[PartyParams(*c.params) for c in clients],
        server_params=server.params,
        protocol=protocol_meta,
        overlap_reps=overlap_reps,
        metric_name=metric_name,
        metric=float(metric),
    )


# ------------------------------------------------------------- persistence
def _param_tree(art: TrainedVFLModel) -> dict:
    tree = {"clients": [{"extractor": p.extractor, "head": p.head}
                        for p in art.client_params],
            "server": art.server_params}
    if art.overlap_reps is not None:
        tree["overlap_reps"] = list(art.overlap_reps)
    return tree


def save_artifact(directory: str, art: TrainedVFLModel) -> str:
    """Persist one deployment artifact per directory (atomic, via
    ``save_checkpoint``): parameters as the pytree, the typed declarative
    fields as checkpoint metadata."""
    meta = {
        "artifact_version": art.version,
        "scenario": art.scenario,
        "num_classes": art.num_classes,
        "feature_shapes": [list(s) for s in art.feature_shapes],
        "extractor_specs": [s.to_meta() for s in art.extractor_specs],
        "protocol": dict(art.protocol),
        "metric_name": art.metric_name,
        "metric": float(art.metric),
        "n_overlap": (int(art.overlap_reps[0].shape[0])
                      if art.overlap_reps is not None else None),
    }
    return save_checkpoint(directory, _ARTIFACT_STEP, _param_tree(art), meta)


def _template(meta: dict) -> TrainedVFLModel:
    """Reconstruct a zero-parameter artifact of the metadata's geometry —
    the load template (treedef + shapes + dtypes) ``load_checkpoint``
    restores into."""
    specs = tuple(ExtractorSpec.from_meta(m) for m in meta["extractor_specs"])
    shapes = tuple(tuple(s) for s in meta["feature_shapes"])
    num_classes = meta["num_classes"]
    key = jax.random.PRNGKey(0)          # values are overwritten on load
    client_params, rep_dims = [], []
    for spec, shape in zip(specs, shapes):
        ext = spec.build()
        sample = jnp.zeros((2,) + shape, jnp.float32)
        e_params = ext.init(key, sample)
        head = make_classifier(num_classes)
        h_params = head.init(key, ext.apply(e_params, sample[:1]))
        client_params.append(PartyParams(e_params, h_params))
        rep_dims.append(spec.rep_dim)
    clf = make_classifier(num_classes)
    server_params = clf.init(key, jnp.zeros((1, sum(rep_dims)), jnp.float32))
    overlap = None
    if meta.get("n_overlap") is not None:
        overlap = [jnp.zeros((meta["n_overlap"], d), jnp.float32)
                   for d in rep_dims]
    return TrainedVFLModel(
        scenario=meta["scenario"], num_classes=num_classes,
        feature_shapes=shapes, extractor_specs=specs,
        client_params=client_params, server_params=server_params,
        protocol=dict(meta.get("protocol", {})), overlap_reps=overlap,
        metric_name=meta.get("metric_name", ""),
        metric=float(meta.get("metric", 0.0)),
        version=meta["artifact_version"])


def load_artifact(directory: str) -> TrainedVFLModel:
    """Load a deployment artifact: metadata → rebuild the typed template
    from the specs alone → restore the parameter pytree into it."""
    # probe the metadata first (template=empty tree restores nothing)
    _, meta = load_checkpoint(directory, template={}, step=_ARTIFACT_STEP)
    version = meta.get("artifact_version")
    if version is None or version > ARTIFACT_VERSION:
        raise ValueError(
            f"{directory}: not a VFL serving artifact, or version "
            f"{version!r} is newer than supported ({ARTIFACT_VERSION})")
    art = _template(meta)
    tree, _ = load_checkpoint(directory, template=_param_tree(art),
                              step=_ARTIFACT_STEP)
    art.client_params = [PartyParams(c["extractor"], c["head"])
                         for c in tree["clients"]]
    art.server_params = tree["server"]
    if "overlap_reps" in tree:
        art.overlap_reps = list(tree["overlap_reps"])
    return art
