"""Pytree <-> npz checkpointing with atomic writes and step indexing.

Layout: <dir>/ckpt_<step>.npz holding flattened leaves keyed by path string,
plus a JSON-encoded treedef/metadata entry. Works for any pytree of arrays.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> Tuple[dict, Any]:
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    flat = {f"leaf_{i}": np.asarray(x) for i, x in enumerate(leaves)}
    return flat, treedef


def save_checkpoint(directory: str, step: int, tree: Any, metadata: Optional[dict] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat, _ = _flatten_with_paths(tree)
    meta = dict(metadata or {})
    meta["step"] = int(step)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, __meta__=np.frombuffer(json.dumps(meta).encode(), dtype=np.uint8), **flat)
        os.replace(tmp, path)  # atomic on POSIX
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    return path


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(f[len("ckpt_"):-len(".npz")]) for f in os.listdir(directory)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else None


def load_checkpoint(directory: str, template: Any, step: Optional[int] = None) -> Tuple[Any, dict]:
    """Load into the structure of ``template`` (used for treedef + dtypes)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    blob = np.load(path)
    meta = json.loads(bytes(blob["__meta__"]).decode())
    leaves, treedef = jax.tree_util.tree_flatten(template)
    restored = []
    for i, leaf in enumerate(leaves):
        arr = blob[f"leaf_{i}"]
        assert arr.shape == tuple(np.shape(leaf)), (i, arr.shape, np.shape(leaf))
        tmpl_dtype = jnp.asarray(leaf).dtype
        if arr.dtype.kind == "V":
            # np.savez stores ml_dtypes leaves (bfloat16, …) as raw void
            # bytes; the template's dtype reinterprets the bit pattern
            arr = arr.view(np.dtype(tmpl_dtype))
        restored.append(jnp.asarray(arr, dtype=tmpl_dtype))
    return jax.tree_util.tree_unflatten(treedef, restored), meta
