"""Pallas TPU kernels for the paper's compute hot-spots.

* ``kmeans``          — pairwise-distance + argmin assignment (step ③).
* ``sdpa_estimator``  — flash-style blocked SDPA representation estimation
                        (Eq. 10, the few-shot server hot-spot: N_u ≫ N_o).
* ``decode_attention`` — GQA flash-decode for the serving stack of the
                        assigned architectures.
* ``rmsnorm``         — fused RMSNorm (two per layer in every assigned
                        arch; memory-bound floor of 1R+1W per element).

Each kernel directory has kernel.py (pl.pallas_call + BlockSpec), ops.py
(jit'd public wrapper with padding/dtype plumbing) and ref.py (pure-jnp
oracle used by the tests' assert_allclose sweeps).

Kernels run in interpret mode on CPU (``REPRO_KERNEL_INTERPRET=1`` or
automatically when no TPU is present); on TPU they compile natively.
"""
import os

import jax


def interpret_mode() -> bool:
    env = os.environ.get("REPRO_KERNEL_INTERPRET")
    if env is not None:
        return env not in ("0", "false", "False")
    return jax.default_backend() != "tpu"
