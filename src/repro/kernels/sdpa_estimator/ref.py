"""Pure-jnp oracle for the SDPA representation-estimation kernel (Eq. 10)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sdpa_estimate(h_u: jnp.ndarray, h_o_a: jnp.ndarray, h_o_b: jnp.ndarray
                  ) -> jnp.ndarray:
    """Ĥ_u^B = softmax(H_u^A H_o^Aᵀ / √d) H_o^B.

    h_u: (N_u, d), h_o_a: (N_o, d), h_o_b: (N_o, d_b) → (N_u, d_b) f32.
    """
    h_u = h_u.astype(jnp.float32)
    h_o_a = h_o_a.astype(jnp.float32)
    h_o_b = h_o_b.astype(jnp.float32)
    d = h_u.shape[-1]
    scores = (h_u @ h_o_a.T) / jnp.sqrt(jnp.asarray(d, jnp.float32))
    return jax.nn.softmax(scores, axis=-1) @ h_o_b
