"""Pallas TPU kernel: flash-style blocked SDPA representation estimation.

The few-shot server evaluates Ĥ_u = softmax(H_u H_oᵀ/√d) H_o^B with
N_u ≫ N_o (every client's full private pool attends over the overlap set).
Materializing the (N_u, N_o) score matrix in HBM is the naive cost; the
kernel streams key/value blocks through VMEM with an online softmax so the
score tile only ever lives in VREGs/VMEM — the standard FlashAttention
recurrence adapted to this asymmetric (cross-attention, no causality, no
multi-head) shape.

Grid: (N_u/BU, N_o/BO); the u-axis is parallel, the o-axis is a sequential
reduction carried in VMEM scratch (m, l, acc). Block shapes are MXU-aligned
multiples of (8, 128); ops.py pads inputs and picks BU/BO under the VMEM
budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _sdpa_kernel(no_valid: int,
                 q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref):
    """q is pre-scaled by 1/√d in ops.py (python-float closure constants are
    rejected by pallas_call, and pre-scaling saves a VPU pass anyway)."""
    j = pl.program_id(1)
    nj = pl.num_programs(1)
    bo = k_ref.shape[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)                  # (BU, d)
    k = k_ref[...].astype(jnp.float32)                  # (BO, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BU, BO)
    col = j * bo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < no_valid, s, _NEG_INF)

    m_prev = m_ref[..., :1]                             # (BU, 1)
    l_prev = l_ref[..., :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                              # (BU, BO)
    alpha = jnp.exp(m_prev - m_new)                     # (BU, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v_ref[...].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (BU, db)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nj - 1)
    def _finish():
        o_ref[...] = (acc_ref[...] / l_ref[..., :1]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("no_valid", "block_u", "block_o", "interpret"))
def sdpa_estimate_padded(h_u: jnp.ndarray, h_o_a: jnp.ndarray, h_o_b: jnp.ndarray,
                         no_valid: int,
                         block_u: int = 256, block_o: int = 256,
                         interpret: bool = False) -> jnp.ndarray:
    """h_u must already be scaled by 1/√d_true."""
    nu, d = h_u.shape
    no, db = h_o_b.shape
    assert nu % block_u == 0 and no % block_o == 0
    grid = (nu // block_u, no // block_o)
    kernel = functools.partial(_sdpa_kernel, no_valid)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_u, d), lambda i, j: (i, 0)),
            pl.BlockSpec((block_o, d), lambda i, j: (j, 0)),
            pl.BlockSpec((block_o, db), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((block_u, db), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nu, db), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_u, 128), jnp.float32),   # m
            pltpu.VMEM((block_u, 128), jnp.float32),   # l
            pltpu.VMEM((block_u, db), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(h_u, h_o_a, h_o_b)
