"""Pallas TPU kernel: flash-style blocked SDPA representation estimation.

The few-shot server evaluates Ĥ_u = softmax(H_u H_oᵀ/√d) H_o^B with
N_u ≫ N_o (every client's full private pool attends over the overlap set).
Materializing the (N_u, N_o) score matrix in HBM is the naive cost; the
kernel streams key/value blocks through VMEM with an online softmax so the
score tile only ever lives in VREGs/VMEM — the standard FlashAttention
recurrence adapted to this asymmetric (cross-attention, no causality, no
multi-head) shape.

Batch is a NATIVE leading grid dimension (DESIGN.md §15): the batched entry
runs a ``(B, N_u/BU, N_o/BO)`` grid. TPU grids iterate row-major with the
LAST axis fastest, so for every fixed (b, i) the o-axis programs
``j = 0 … nj−1`` still run back-to-back — the m/l/acc scratch recurrence
(init at ``j == 0``, write-out at ``j == nj−1``) is untouched by the extra
leading axis. One launch estimates a whole stacked seed fold (or a served
partial-party batch) instead of B sequential launches. The single-entry
grid is literally the ``B = 1`` case.

Grid: (B, N_u/BU, N_o/BO); b and the u-axis are parallel, the o-axis is a
sequential reduction carried in VMEM scratch (m, l, acc). The batch block
width is 1, so per-instance VMEM is identical to the unbatched grid. Block
shapes are MXU-aligned multiples of (8, 128); ops.py pads inputs and picks
BU/BO under the VMEM budget.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _sdpa_kernel(no_valid: int,
                 q_ref, k_ref, v_ref, o_ref,
                 m_ref, l_ref, acc_ref):
    """q is pre-scaled by 1/√d in ops.py (python-float closure constants are
    rejected by pallas_call, and pre-scaling saves a VPU pass anyway)."""
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    bo = k_ref.shape[1]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)                    # (BU, d)
    k = k_ref[0].astype(jnp.float32)                    # (BO, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (BU, BO)
    col = j * bo + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < no_valid, s, _NEG_INF)

    m_prev = m_ref[..., :1]                             # (BU, 1)
    l_prev = l_ref[..., :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)                              # (BU, BO)
    alpha = jnp.exp(m_prev - m_new)                     # (BU, 1)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (BU, db)
    acc_ref[...] = acc_ref[...] * alpha + pv
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nj - 1)
    def _finish():
        o_ref[0, ...] = (acc_ref[...] / l_ref[..., :1]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("no_valid", "block_u", "block_o", "interpret"))
def sdpa_estimate_batched_padded(h_u: jnp.ndarray, h_o_a: jnp.ndarray,
                                 h_o_b: jnp.ndarray, no_valid: int,
                                 block_u: int = 256, block_o: int = 256,
                                 interpret: bool = False) -> jnp.ndarray:
    """h_u (B, N_u, d), h_o_a (B, N_o, d), h_o_b (B, N_o, d_b) → (B, N_u, d_b).

    h_u must already be scaled by 1/√d_true; all B entries share one
    ``no_valid`` (ops.py pads every entry to a common plan)."""
    b, nu, d = h_u.shape
    _, no, db = h_o_b.shape
    assert nu % block_u == 0 and no % block_o == 0
    grid = (b, nu // block_u, no // block_o)
    kernel = functools.partial(_sdpa_kernel, no_valid)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_u, d), lambda bi, i, j: (bi, i, 0)),
            pl.BlockSpec((1, block_o, d), lambda bi, i, j: (bi, j, 0)),
            pl.BlockSpec((1, block_o, db), lambda bi, i, j: (bi, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_u, db), lambda bi, i, j: (bi, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, nu, db), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((block_u, 128), jnp.float32),   # m
            pltpu.VMEM((block_u, 128), jnp.float32),   # l
            pltpu.VMEM((block_u, db), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(h_u, h_o_a, h_o_b)


@functools.partial(jax.jit,
                   static_argnames=("no_valid", "block_u", "block_o", "interpret"))
def sdpa_estimate_padded(h_u: jnp.ndarray, h_o_a: jnp.ndarray, h_o_b: jnp.ndarray,
                         no_valid: int,
                         block_u: int = 256, block_o: int = 256,
                         interpret: bool = False) -> jnp.ndarray:
    """The width-1 case of the batched grid. h_u pre-scaled by 1/√d_true."""
    return sdpa_estimate_batched_padded(
        h_u[None], h_o_a[None], h_o_b[None], no_valid=no_valid,
        block_u=block_u, block_o=block_o, interpret=interpret)[0]
