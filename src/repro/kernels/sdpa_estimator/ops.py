"""Public wrappers: padding, block sizing, the √d scale from the TRUE dim.

When does this beat the XLA reference?  The jnp oracle materializes the
(N_u, N_o) score matrix plus its softmax in HBM; the flash-style kernel
keeps score tiles in VMEM with an online-softmax recurrence, so it wins in
the few-shot regime the paper targets — N_u ≫ N_o (every client's private
pool attending over the overlap set), where the score matrix is the
dominant HBM traffic.  With both N_u and N_o small (≲1k) XLA's fusion is
already roofline-bound on the matmuls and the kernel only breaks even.

The batched entry (``sdpa_estimate_batched``) folds a stacked seed axis (or
a served partial-party batch) into the grid itself — ONE
(B, N_u/BU, N_o/BO) launch versus B sequential launches: one dispatch, one
padding plan, one trace instead of B of each. Measured on the bench shapes
(B=8, N_u=4096, N_o=256, d=128; CPU interpret mode,
``benchmarks/kernels_bench.py`` / BENCH_kernels.json): the batched grid
matches the vmapped jnp oracle to ≤1e-5 (maxerr ~1e-6), but — as with
kmeans — interpret-mode wall-clock does NOT show the win: the
interpreter's per-grid-step cost dominates, B sequential launches time
about the same as the one B-grid launch (grid_vs_seq ≈ 0.5×), and the
vmapped XLA reference is ~3× faster outright. Under interpretation Pallas
is strictly overhead (the KernelRouter routes it off on CPU); the batched
grid's payoff is on TPU, where the amortized dispatch/pad cost is real and
the (N_u, N_o) score tile never touches HBM. ``KernelRouter`` in
``launch/vfl_serve.py`` encodes the B·N_u·N_o roofline rule.

VMEM budget per grid instance (f32) — the leading batch axis has block
width 1, so per-instance VMEM is identical to the unbatched grid and
``_pick_blocks`` is batch-independent:

  tile              shape        purpose
  q row-tile        (BU, d)      H_u block (pre-scaled by 1/√d_true)
  k tile            (BO, d)      H_o^A block (sequential reduction axis)
  v tile            (BO, d_b)    H_o^B block
  acc / out         (BU, d_b)    online-softmax accumulator + output
  m, l scratch      (BU, 128)    running max / normalizer lanes
  score tile        (BU, BO)     lives only in VREGs/VMEM, never HBM

``_pick_blocks`` shrinks BU=BO from 512 down until the sum fits the 12 MB
``_VMEM_BUDGET`` (headroom under ~16 MB/core). Blocks are MXU-aligned
multiples of (8, 128); d and d_b are padded to 128 lanes.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.sdpa_estimator.kernel import (sdpa_estimate_batched_padded,
                                                 sdpa_estimate_padded)

_LANE = 128
_VMEM_BUDGET = 12 * 2**20

assert sdpa_estimate_padded is not None  # width-1 entry, re-exported


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_blocks(d_pad: int, db_pad: int):
    for b in (512, 256, 128, 64, 32, 16, 8):
        # q + k + v + acc + m/l + out tiles, f32
        vmem = 4 * (b * d_pad + b * d_pad + b * db_pad + b * db_pad
                    + 2 * b * 128 + b * db_pad + b * b)
        if vmem <= _VMEM_BUDGET:
            return b, b
    return 8, 8


def sdpa_estimate_batched(h_u: jnp.ndarray, h_o_a: jnp.ndarray,
                          h_o_b: jnp.ndarray) -> jnp.ndarray:
    """Eq. 10 per batch entry as ONE batched grid launch.

    h_u (B, N_u, d), h_o_a (B, N_o, d), h_o_b (B, N_o, d_b) →
    (B, N_u, d_b) f32. Any shapes; all entries share one padding plan (they
    already share shapes — the batch axis is a stacked fold axis)."""
    b, nu, d = h_u.shape
    _, no, d2 = h_o_a.shape
    assert d == d2, (d, d2)
    db = h_o_b.shape[2]
    assert h_o_b.shape[1] == no

    d_pad = _round_up(max(d, _LANE), _LANE)
    db_pad = _round_up(max(db, _LANE), _LANE)
    bu, bo = _pick_blocks(d_pad, db_pad)
    nu_pad = _round_up(max(nu, bu), bu)
    no_pad = _round_up(max(no, bo), bo)

    scale = 1.0 / (d ** 0.5)   # √d of the TRUE dim, not the padded one
    qp = jnp.zeros((b, nu_pad, d_pad), jnp.float32).at[:, :nu, :d].set(
        h_u.astype(jnp.float32) * scale)
    kp = jnp.zeros((b, no_pad, d_pad), jnp.float32
                   ).at[:, :no, :d].set(h_o_a.astype(jnp.float32))
    vp = jnp.zeros((b, no_pad, db_pad), jnp.float32
                   ).at[:, :no, :db].set(h_o_b.astype(jnp.float32))

    out = sdpa_estimate_batched_padded(qp, kp, vp, no_valid=no,
                                       block_u=bu, block_o=bo,
                                       interpret=interpret_mode())
    return out[:, :nu, :db]


def sdpa_estimate(h_u: jnp.ndarray, h_o_a: jnp.ndarray, h_o_b: jnp.ndarray
                  ) -> jnp.ndarray:
    """Eq. 10 via the Pallas kernel. Any shapes; returns (N_u, d_b) f32.

    The width-1 case of :func:`sdpa_estimate_batched` — same padding plan,
    same grid program."""
    return sdpa_estimate_batched(h_u[None], h_o_a[None], h_o_b[None])[0]
