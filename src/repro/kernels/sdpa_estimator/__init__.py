from repro.kernels.sdpa_estimator import ops, ref

__all__ = ["ops", "ref"]
