"""Public wrapper: GQA grouping, padding, block sizing."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.decode_attention.kernel import decode_attention_padded

_LANE = 128
_SUBLANE = 8
_VMEM_BUDGET = 12 * 2**20


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray
                     ) -> jnp.ndarray:
    """q (B, H, dh), caches (B, Hkv, S, dh) → (B, H, dh) f32."""
    b, h, dh = q.shape
    _, hkv, s, _ = k_cache.shape
    assert h % hkv == 0
    g = h // hkv

    dh_pad = _round_up(max(dh, _LANE), _LANE)
    g_pad = _round_up(max(g, _SUBLANE), _SUBLANE)
    # block_s sized to the VMEM budget: k + v blocks dominate
    block_s = 512
    while 4 * (2 * block_s * dh_pad + 2 * g_pad * dh_pad + g_pad * block_s) > _VMEM_BUDGET:
        block_s //= 2
    block_s = max(block_s, _SUBLANE)
    s_pad = _round_up(max(s, block_s), block_s)

    scale = 1.0 / (dh ** 0.5)
    qg = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, dh)
    qp = jnp.zeros((b, hkv, g_pad, dh_pad), jnp.float32).at[:, :, :g, :dh].set(qg)
    kp = jnp.zeros((b, hkv, s_pad, dh_pad), jnp.float32).at[:, :, :s, :dh].set(
        k_cache.astype(jnp.float32))
    vp = jnp.zeros((b, hkv, s_pad, dh_pad), jnp.float32).at[:, :, :s, :dh].set(
        v_cache.astype(jnp.float32))

    out = decode_attention_padded(qp, kp, vp, s_valid=s, block_s=block_s,
                                  interpret=interpret_mode())
    return out[:, :, :g, :dh].reshape(b, h, dh)
