"""Public wrapper: GQA grouping, padding, block sizing.

When does this beat the XLA reference?  Single-token decode attention is
memory-bound on the KV cache: the roofline floor is streaming |KV| bytes
HBM→VMEM once per step.  The jnp oracle materializes the (G, S) score row
and its softmax in HBM between two separate matmuls; the kernel's
online-softmax walk touches the cache exactly once, so it wins at long
context (S ≳ 8k, and increasingly up to the 32k–500k serving shapes) where
score-row traffic is comparable to the cache itself.  At short S the whole
problem fits in cache and XLA's fusion is equally fast.

VMEM budget per grid instance (f32), following the kmeans/kernel.py layout:

  tile              shape         bytes (BS=512, dh=128, G=8)
  k cache block     (BS, dh)      512·128·4 ≈ 256 KB
  v cache block     (BS, dh)      512·128·4 ≈ 256 KB
  q group rows      (G,  dh)      8·128·4   ≈ 4 KB
  acc scratch       (G,  dh)      8·128·4   ≈ 4 KB
  score tile        (G,  BS)      8·512·4   ≈ 16 KB

The block_s loop halves BS from 512 until 2·BS·dh + 2·G·dh + G·BS floats
fit the 12 MB ``_VMEM_BUDGET`` (headroom under ~16 MB/core).  dh is padded
to 128 lanes, the query group to the 8-sublane minimum.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.decode_attention.kernel import decode_attention_padded

_LANE = 128
_SUBLANE = 8
_VMEM_BUDGET = 12 * 2**20


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray
                     ) -> jnp.ndarray:
    """q (B, H, dh), caches (B, Hkv, S, dh) → (B, H, dh) f32."""
    b, h, dh = q.shape
    _, hkv, s, _ = k_cache.shape
    assert h % hkv == 0
    g = h // hkv

    dh_pad = _round_up(max(dh, _LANE), _LANE)
    g_pad = _round_up(max(g, _SUBLANE), _SUBLANE)
    # block_s sized to the VMEM budget: k + v blocks dominate
    block_s = 512
    while 4 * (2 * block_s * dh_pad + 2 * g_pad * dh_pad + g_pad * block_s) > _VMEM_BUDGET:
        block_s //= 2
    block_s = max(block_s, _SUBLANE)
    s_pad = _round_up(max(s, block_s), block_s)

    scale = 1.0 / (dh ** 0.5)
    qg = (q.astype(jnp.float32) * scale).reshape(b, hkv, g, dh)
    qp = jnp.zeros((b, hkv, g_pad, dh_pad), jnp.float32).at[:, :, :g, :dh].set(qg)
    kp = jnp.zeros((b, hkv, s_pad, dh_pad), jnp.float32).at[:, :, :s, :dh].set(
        k_cache.astype(jnp.float32))
    vp = jnp.zeros((b, hkv, s_pad, dh_pad), jnp.float32).at[:, :, :s, :dh].set(
        v_cache.astype(jnp.float32))

    out = decode_attention_padded(qp, kp, vp, s_valid=s, block_s=block_s,
                                  interpret=interpret_mode())
    return out[:, :, :g, :dh].reshape(b, h, dh)
