from repro.kernels.decode_attention import ops, ref

__all__ = ["ops", "ref"]
