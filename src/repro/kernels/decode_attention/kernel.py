"""Pallas TPU kernel: GQA flash-decode (one query token vs a long KV cache).

Decode attention at seq 32k-500k is memory-bound: the whole KV cache streams
HBM→VMEM once per step while compute is a (G, dh)·(dh, BS) matvec-batch per
block. The kernel keeps the online-softmax running state (m, l, acc) for the
G grouped query heads in VMEM scratch and walks the cache in BS-sized blocks,
so HBM traffic is exactly |KV| bytes — the roofline floor.

Grid: (B, Hkv, S/BS); (batch, kv-head) axes parallel, cache-block axis is the
sequential reduction. q rows for one kv head = the G query heads of its group
(G = H/Hkv ≥ 1), padded to the 8-sublane minimum by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _decode_kernel(s_valid: int, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref):
    j = pl.program_id(2)
    nj = pl.num_programs(2)
    bs = k_ref.shape[2]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)      # (G, dh), pre-scaled by 1/√dh
    k = k_ref[0, 0].astype(jnp.float32)      # (BS, dh)
    v = v_ref[0, 0].astype(jnp.float32)      # (BS, dh)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (G, BS)
    col = j * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(col < s_valid, s, _NEG_INF)

    m_prev = m_ref[..., :1]
    l_prev = l_ref[..., :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(j == nj - 1)
    def _finish():
        o_ref[0, 0] = (acc_ref[...] / l_ref[..., :1]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("s_valid", "block_s", "interpret"))
def decode_attention_padded(q: jnp.ndarray, k_cache: jnp.ndarray,
                            v_cache: jnp.ndarray, s_valid: int,
                            block_s: int = 512, interpret: bool = False
                            ) -> jnp.ndarray:
    """q (B, Hkv, G, dh) pre-scaled; caches (B, Hkv, S, dh); S % block_s == 0."""
    b, hkv, g, dh = q.shape
    s = k_cache.shape[2]
    assert s % block_s == 0
    grid = (b, hkv, s // block_s)
    kernel = functools.partial(_decode_kernel, s_valid)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, g, dh), lambda b_, h_, j_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_s, dh), lambda b_, h_, j_: (b_, h_, j_, 0)),
            pl.BlockSpec((1, 1, block_s, dh), lambda b_, h_, j_: (b_, h_, j_, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, dh), lambda b_, h_, j_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, dh), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, 128), jnp.float32),
            pltpu.VMEM((g, dh), jnp.float32),
        ],
        interpret=interpret,
    )(q, k_cache, v_cache)
