"""Pure-jnp oracle for GQA flash-decode attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     lengths: jnp.ndarray | None = None) -> jnp.ndarray:
    """Single-token GQA attention against a KV cache.

    q:       (B, H, dh)          — one new query token per sequence
    k_cache: (B, Hkv, S, dh)
    v_cache: (B, Hkv, S, dh)
    lengths: (B,) int32 valid-prefix lengths (None → all S valid)
    returns  (B, H, dh) f32
    """
    b, h, dh = q.shape
    _, hkv, s, _ = k_cache.shape
    g = h // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, dh)
    kf = k_cache.astype(jnp.float32)
    vf = v_cache.astype(jnp.float32)
    scores = jnp.einsum("bkgd,bksd->bkgs", qf, kf) / jnp.sqrt(
        jnp.asarray(dh, jnp.float32))
    if lengths is not None:
        mask = jnp.arange(s)[None, None, None, :] < lengths[:, None, None, None]
        scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", p, vf)
    return out.reshape(b, h, dh)
