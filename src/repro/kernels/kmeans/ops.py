"""Public jit'd wrapper: padding, VMEM-budget block sizing, dtype plumbing."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.kmeans.kernel import kmeans_assign_padded

_LANE = 128     # MXU/VREG lane width
_SUBLANE = 8
_VMEM_BUDGET = 12 * 2**20   # leave headroom under ~16 MB/core


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block_n(d_pad: int, c_pad: int) -> int:
    for bn in (512, 256, 128, 64, 32, 16, 8):
        vmem = 4 * (bn * d_pad + c_pad * d_pad + 2 * bn * c_pad)
        if vmem <= _VMEM_BUDGET:
            return bn
    return 8


def kmeans_assign(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """argmin_c ‖x_i − μ_c‖² via the Pallas kernel. Any N, d, C."""
    n, d = x.shape
    c = centers.shape[0]
    d_pad = _round_up(max(d, _LANE), _LANE)
    c_pad = _round_up(max(c, _SUBLANE), _SUBLANE)
    bn = _pick_block_n(d_pad, c_pad)
    n_pad = _round_up(max(n, bn), bn)

    xp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(x.astype(jnp.float32))
    # Sentinel rows: huge coordinates → huge distance → never the argmin.
    cp = jnp.full((c_pad, d_pad), 0.0, jnp.float32)
    cp = cp.at[:c, :d].set(centers.astype(jnp.float32))
    if c_pad > c:
        cp = cp.at[c:, 0].set(3e18)

    out = kmeans_assign_padded(xp, cp, block_n=bn, interpret=interpret_mode())
    return out[:n]
