"""Public jit'd wrappers: padding, VMEM-budget block sizing, dtype plumbing.

When does this beat the XLA reference?  The jnp oracle materializes the full
(N, C) distance matrix in HBM before the argmin; the kernel fuses distance
formation and the argmin reduction in VMEM, so it wins once N·C is large
enough that the distance matrix spills past cache — in this repo, the
one-shot step-③ shape (N_o gradient rows × C classes) with N_o ≥ ~2k.
For tiny N (few hundred rows) the launch overhead makes XLA's fused
expansion just as fast; that's why ``use_kernels`` defaults to off in
``ProtocolConfig`` and tests pin the jnp path as the numerical oracle.

The batched entry (``kmeans_assign_batched``) folds a stacked S·C·K axis
into the grid itself — ONE launch for the whole fold versus B sequential
width-1 launches or a vmap replay: one dispatch, one pad plan, one trace
instead of B of each. Measured on the bench shapes (B=8, N=2048, d=128,
C=10; CPU interpret mode, ``benchmarks/kernels_bench.py`` /
BENCH_kernels.json): the batched grid is bit-equal to the vmapped jnp
oracle, but interpret-mode wall-clock does NOT show the win — the
interpreter's per-grid-step cost dominates, so the B-grid launch times
about the same as B sequential launches (grid_vs_seq ≈ 0.7×) and the
XLA reference is ~20× faster outright. That is expected: under
interpretation Pallas is strictly overhead (the KernelRouter routes it
off everywhere on CPU). The batched grid's payoff is on TPU, where the
per-launch dispatch/pad cost it amortizes is real and the distance tile
never leaves VMEM; the roofline note above governs when to flip
``use_kernels``.

VMEM budget per grid instance (f32), mirroring kmeans/kernel.py — the
leading batch axis has block width 1 and adds NOTHING per instance, so
block sizing is batch-independent:

  tile              shape        bytes (BN=256, d=4096, C=1024 worst case)
  x row-tile        (BN, d)      256·4096·4 ≈ 4.2 MB
  centers           (C,  d)      1024·4096·4 ≈ 16.8 MB
  distance tile     (BN, C)      256·1024·4 ≈ 1.0 MB

``_pick_block_n`` clamps BN down until the working set fits the
``_VMEM_BUDGET`` (12 MB, headroom under the ~16 MB/core of TPU v5e).
MXU alignment: BN multiple of 8; d and C padded to multiples of 128.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.kmeans.kernel import (kmeans_assign_batched_padded,
                                         kmeans_assign_padded)

_LANE = 128     # MXU/VREG lane width
_SUBLANE = 8
_VMEM_BUDGET = 12 * 2**20   # leave headroom under ~16 MB/core

assert kmeans_assign_padded is not None  # width-1 entry, re-exported


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block_n(d_pad: int, c_pad: int) -> int:
    for bn in (512, 256, 128, 64, 32, 16, 8):
        vmem = 4 * (bn * d_pad + c_pad * d_pad + 2 * bn * c_pad)
        if vmem <= _VMEM_BUDGET:
            return bn
    return 8


def _pad_plan(n: int, d: int, c: int):
    d_pad = _round_up(max(d, _LANE), _LANE)
    c_pad = _round_up(max(c, _SUBLANE), _SUBLANE)
    bn = _pick_block_n(d_pad, c_pad)
    n_pad = _round_up(max(n, bn), bn)
    return n_pad, d_pad, c_pad, bn


def kmeans_assign_batched(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """argmin_c ‖x_{b,i} − μ_{b,c}‖² per batch entry, ONE (B, N/BN) grid.

    x (B, N, d), centers (B, C, d) → (B, N) int32. Any N, d, C; the batch
    axis is the stacked fold axis (seeds × scenarios × parties upstream)."""
    b, n, d = x.shape
    c = centers.shape[1]
    n_pad, d_pad, c_pad, bn = _pad_plan(n, d, c)

    xp = jnp.zeros((b, n_pad, d_pad), jnp.float32
                   ).at[:, :n, :d].set(x.astype(jnp.float32))
    # Sentinel rows: huge coordinates → huge distance → never the argmin.
    cp = jnp.zeros((b, c_pad, d_pad), jnp.float32
                   ).at[:, :c, :d].set(centers.astype(jnp.float32))
    if c_pad > c:
        cp = cp.at[:, c:, 0].set(3e18)

    out = kmeans_assign_batched_padded(xp, cp, block_n=bn,
                                       interpret=interpret_mode())
    return out[:, :n]


def kmeans_assign(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """argmin_c ‖x_i − μ_c‖² via the Pallas kernel. Any N, d, C.

    The width-1 case of :func:`kmeans_assign_batched` — same padding plan,
    same grid program."""
    return kmeans_assign_batched(x[None], centers[None])[0]
