"""Public jit'd wrapper: padding, VMEM-budget block sizing, dtype plumbing.

When does this beat the XLA reference?  The jnp oracle materializes the full
(N, C) distance matrix in HBM before the argmin; the kernel fuses distance
formation and the argmin reduction in VMEM, so it wins once N·C is large
enough that the distance matrix spills past cache — in this repo, the
one-shot step-③ shape (N_o gradient rows × C classes) with N_o ≥ ~2k.
For tiny N (few hundred rows) the launch overhead makes XLA's fused
expansion just as fast; that's why ``use_kernels`` defaults to off in
``ProtocolConfig`` and tests pin the jnp path as the numerical oracle.

VMEM budget per grid instance (f32), mirroring kmeans/kernel.py:

  tile              shape        bytes (BN=256, d=4096, C=1024 worst case)
  x row-tile        (BN, d)      256·4096·4 ≈ 4.2 MB
  centers           (C,  d)      1024·4096·4 ≈ 16.8 MB
  distance tile     (BN, C)      256·1024·4 ≈ 1.0 MB

``_pick_block_n`` clamps BN down until the working set fits the
``_VMEM_BUDGET`` (12 MB, headroom under the ~16 MB/core of TPU v5e).
MXU alignment: BN multiple of 8; d and C padded to multiples of 128.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.kmeans.kernel import kmeans_assign_padded

_LANE = 128     # MXU/VREG lane width
_SUBLANE = 8
_VMEM_BUDGET = 12 * 2**20   # leave headroom under ~16 MB/core


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def _pick_block_n(d_pad: int, c_pad: int) -> int:
    for bn in (512, 256, 128, 64, 32, 16, 8):
        vmem = 4 * (bn * d_pad + c_pad * d_pad + 2 * bn * c_pad)
        if vmem <= _VMEM_BUDGET:
            return bn
    return 8


def kmeans_assign(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """argmin_c ‖x_i − μ_c‖² via the Pallas kernel. Any N, d, C."""
    n, d = x.shape
    c = centers.shape[0]
    d_pad = _round_up(max(d, _LANE), _LANE)
    c_pad = _round_up(max(c, _SUBLANE), _SUBLANE)
    bn = _pick_block_n(d_pad, c_pad)
    n_pad = _round_up(max(n, bn), bn)

    xp = jnp.zeros((n_pad, d_pad), jnp.float32).at[:n, :d].set(x.astype(jnp.float32))
    # Sentinel rows: huge coordinates → huge distance → never the argmin.
    cp = jnp.full((c_pad, d_pad), 0.0, jnp.float32)
    cp = cp.at[:c, :d].set(centers.astype(jnp.float32))
    if c_pad > c:
        cp = cp.at[c:, 0].set(3e18)

    out = kmeans_assign_padded(xp, cp, block_n=bn, interpret=interpret_mode())
    return out[:n]
