"""Pallas TPU kernel: blocked pairwise-distance + argmin cluster assignment.

Tiling: the grid walks row-blocks of x; each program instance loads an
(BN, d) tile of points and the full (C, d) center matrix into VMEM (C is the
class count — ≤ a few hundred — so centers always fit), forms the distance
tile with one MXU matmul (‖x‖² − 2·x·μᵀ + ‖μ‖²) and reduces the argmin across
the padded C lanes in VREGs.

Batch is a NATIVE leading grid dimension (DESIGN.md §15): the batched entry
runs a ``(B, N/BN)`` grid in which program ``(b, i)`` assigns row-block ``i``
of batch entry ``b`` against that entry's own center matrix — one launch for
a whole stacked S·C·K fold instead of B sequential launches or a ``vmap``
replay of the single-entry program. The single-entry grid is literally the
``B = 1`` case.

VMEM budget per instance (f32): BN·d + C·d + BN·C floats — the leading batch
axis contributes nothing per program (its block width is 1).
With BN=256, d≤4096, C≤1024: 256·4096·4 + 1024·4096·4 + 256·1024·4 ≈ 21.3 MB
worst case — ops.py clamps BN down when d·C is large so the working set stays
within the ~16 MB/core VMEM of TPU v5e. MXU alignment: BN multiple of 8,
d and C padded to multiples of 128 by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_assign_kernel(x_ref, c_ref, out_ref):
    x = x_ref[0].astype(jnp.float32)            # (BN, d)
    cen = c_ref[0].astype(jnp.float32)          # (C, d)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)                   # (BN, 1)
    c2 = jnp.sum(cen * cen, axis=1)[None, :]                     # (1, C)
    # MXU: (BN, d) @ (d, C)
    dots = jax.lax.dot_general(x, cen, (((1,), (1,)), ((), ())),
                               preferred_element_type=jnp.float32)
    dist = x2 - 2.0 * dots + c2                                  # (BN, C)
    out_ref[0, :] = jnp.argmin(dist, axis=1).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_batched_padded(x: jnp.ndarray, centers: jnp.ndarray,
                                 block_n: int = 256, interpret: bool = False
                                 ) -> jnp.ndarray:
    """x (B, N, d), centers (B, C, d) → (B, N) int32; N % block_n == 0,
    d/C already padded.

    Padded center rows must be filled with +inf-distance sentinels by ops.py
    (i.e. rows of large magnitude) so they never win the argmin.
    """
    b, n, d = x.shape
    _, c, _ = centers.shape
    assert n % block_n == 0, (n, block_n)
    grid = (b, n // block_n)
    return pl.pallas_call(
        _kmeans_assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_n, d), lambda bi, i: (bi, i, 0)),
            pl.BlockSpec((1, c, d), lambda bi, i: (bi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda bi, i: (bi, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=interpret,
    )(x, centers)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def kmeans_assign_padded(x: jnp.ndarray, centers: jnp.ndarray,
                         block_n: int = 256, interpret: bool = False) -> jnp.ndarray:
    """x (N, d), centers (C, d); the width-1 case of the batched grid."""
    return kmeans_assign_batched_padded(x[None], centers[None],
                                        block_n=block_n,
                                        interpret=interpret)[0]
