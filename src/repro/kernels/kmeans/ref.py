"""Pure-jnp oracle for the k-means assignment kernel."""
from __future__ import annotations

import jax.numpy as jnp


def kmeans_assign(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """argmin_c ‖x_i - μ_c‖²  →  (N,) int32.

    x: (N, d) float; centers: (C, d) float.
    """
    x = x.astype(jnp.float32)
    centers = centers.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=1)
    d = x2 - 2.0 * (x @ centers.T) + c2[None, :]
    return jnp.argmin(d, axis=1).astype(jnp.int32)


def kmeans_min_dist(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    x = x.astype(jnp.float32)
    centers = centers.astype(jnp.float32)
    x2 = jnp.sum(x * x, axis=1, keepdims=True)
    c2 = jnp.sum(centers * centers, axis=1)
    d = x2 - 2.0 * (x @ centers.T) + c2[None, :]
    return jnp.min(d, axis=1)
