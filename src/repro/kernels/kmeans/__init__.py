from repro.kernels.kmeans import ops, ref

__all__ = ["ops", "ref"]
