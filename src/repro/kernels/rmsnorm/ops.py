"""Public wrapper: flatten leading dims, pad rows/lanes, dispatch."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.rmsnorm.kernel import rms_norm_padded

_LANE = 128


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """x: (..., d); scale (d,). eps fixed at 1e-6 inside the kernel."""
    del eps
    orig_shape = x.shape
    d = orig_shape[-1]
    n = 1
    for s in orig_shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)

    d_pad = _round_up(max(d, _LANE), _LANE)
    block = 256
    while block > 8 and 4 * (2 * block * d_pad + d_pad) > 12 * 2**20:
        block //= 2
    n_pad = _round_up(max(n, block), block)

    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x2)
    sp = jnp.zeros((1, d_pad), scale.dtype).at[0, :d].set(scale)
    out = rms_norm_padded(xp, sp, d_true=d, block_rows=block,
                          interpret=interpret_mode())
    return out[:n, :d].reshape(orig_shape)
