"""Public wrapper: flatten leading dims, pad rows/lanes, dispatch.

When does this beat the XLA reference?  RMSNorm is memory-bound: the floor
is one HBM read + one write per element.  Unfused, XLA materializes the f32
upcast and the variance reduction as separate HBM round-trips; the kernel
does the square-mean in VREGs over a resident row-tile and writes in the
input dtype, so it wins on large activations (rows·d ≳ a few MB — every
per-layer shape of the assigned archs, e.g. 2048×4096) where the extra
round-trips dominate.  For small shapes XLA usually fuses the chain into
one pass already and there is nothing left to win.

VMEM budget per grid instance (f32), following the kmeans/kernel.py layout:

  tile              shape        bytes (block=256, d=4096)
  x row-tile        (BR, d)      256·4096·4 ≈ 4.2 MB
  out row-tile      (BR, d)      256·4096·4 ≈ 4.2 MB
  scale             (1,  d)      4096·4     ≈ 16 KB

The block-rows loop halves BR from 256 until 2·BR·d + d floats fit the
12 MB budget (headroom under ~16 MB/core). d is padded to 128 lanes; the
mean is computed over the TRUE d, passed statically to the kernel.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import interpret_mode
from repro.kernels.rmsnorm.kernel import rms_norm_padded

_LANE = 128


def _round_up(v: int, m: int) -> int:
    return (v + m - 1) // m * m


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """x: (..., d); scale (d,). eps fixed at 1e-6 inside the kernel."""
    del eps
    orig_shape = x.shape
    d = orig_shape[-1]
    n = 1
    for s in orig_shape[:-1]:
        n *= s
    x2 = x.reshape(n, d)

    d_pad = _round_up(max(d, _LANE), _LANE)
    block = 256
    while block > 8 and 4 * (2 * block * d_pad + d_pad) > 12 * 2**20:
        block //= 2
    n_pad = _round_up(max(n, block), block)

    xp = jnp.zeros((n_pad, d_pad), x.dtype).at[:n, :d].set(x2)
    sp = jnp.zeros((1, d_pad), scale.dtype).at[0, :d].set(scale)
    out = rms_norm_padded(xp, sp, d_true=d, block_rows=block,
                          interpret=interpret_mode())
    return out[:n, :d].reshape(orig_shape)
