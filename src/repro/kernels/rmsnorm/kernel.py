"""Pallas TPU kernel: fused RMSNorm.

Every block of every assigned architecture norms twice per layer; unfused,
XLA materializes the f32 upcast and the variance reduction separately. The
kernel keeps one (BR, d) row-tile in VMEM, does the square-mean reduction in
VREGs and writes the scaled result in the input dtype — one HBM read + one
write per element, the memory-bound floor.

Grid walks row blocks; d is padded to the 128-lane width by ops.py with the
mean computed over the TRUE d (passed statically).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(d_true, x_ref, s_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)                 # (BR, d_pad)
    # padded lanes are zero → sum is over true lanes; divide by TRUE d
    var = jnp.sum(x * x, axis=-1, keepdims=True) / d_true
    y = x * jax.lax.rsqrt(var + 1e-6)
    o_ref[...] = (y * s_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("d_true", "block_rows", "interpret"))
def rms_norm_padded(x: jnp.ndarray, scale: jnp.ndarray, d_true: int,
                    block_rows: int = 256, interpret: bool = False) -> jnp.ndarray:
    n, d = x.shape
    assert n % block_rows == 0
    kernel = functools.partial(_kernel, d_true)
    return pl.pallas_call(
        kernel,
        grid=(n // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=interpret,
    )(x, scale)
