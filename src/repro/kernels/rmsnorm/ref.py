"""Pure-jnp oracle for fused RMSNorm."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """x: (..., d); scale: (d,). Returns x/rms(x)·scale in x.dtype."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return ((xf * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)
