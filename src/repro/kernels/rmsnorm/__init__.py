from repro.kernels.rmsnorm import ops, ref

__all__ = ["ops", "ref"]
