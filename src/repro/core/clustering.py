"""k-means over partial gradients → temporary labels (step ③, Alg. 1 l.28).

The paper's intuition: ∇_{h_i} L for same-class samples point in similar
directions, so clustering the N_o gradient rows into C groups recovers the
server's labels up to permutation — without the labels ever leaving the
server.

Implementation: k-means++ seeding + Lloyd iterations, fully jittable
(lax.fori_loop). The inner assignment (pairwise distance + argmin) is the
compute hot-spot and is served by the Pallas kernel in
``repro.kernels.kmeans`` (enabled with use_kernel=True; the pure-jnp path is
the oracle).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp


def _pairwise_sq_dists(x: jnp.ndarray, centers: jnp.ndarray) -> jnp.ndarray:
    """(N, C) squared euclidean distances, MXU-friendly expansion."""
    x2 = jnp.sum(x * x, axis=1, keepdims=True)          # (N, 1)
    c2 = jnp.sum(centers * centers, axis=1)             # (C,)
    return x2 - 2.0 * (x @ centers.T) + c2[None, :]


def assign_clusters(x: jnp.ndarray, centers: jnp.ndarray, use_kernel: bool = False
                    ) -> jnp.ndarray:
    if use_kernel:
        from repro.kernels.kmeans import ops as kops
        return kops.kmeans_assign(x, centers)
    return jnp.argmin(_pairwise_sq_dists(x, centers), axis=1)


def _kmeanspp_init(key, x: jnp.ndarray, k: int) -> jnp.ndarray:
    """k-means++ seeding (jittable: fori_loop over k)."""
    n = x.shape[0]
    key, k0 = jax.random.split(key)
    first = jax.random.randint(k0, (), 0, n)
    centers0 = jnp.zeros((k, x.shape[1]), x.dtype).at[0].set(x[first])

    def body(i, carry):
        centers, key = carry
        d = _pairwise_sq_dists(x, centers)
        # distances to the i centers chosen so far; rest are masked out
        valid = jnp.arange(k) < i
        dmin = jnp.min(jnp.where(valid[None, :], d, jnp.inf), axis=1)
        dmin = jnp.maximum(dmin, 0.0)
        key, kc = jax.random.split(key)
        probs = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        idx = jax.random.choice(kc, n, p=probs)
        return centers.at[i].set(x[idx]), key

    centers, _ = jax.lax.fori_loop(1, k, body, (centers0, key))
    return centers


def _normalized_search(key, x: jnp.ndarray, num_clusters: int,
                       num_iters: int, restarts: int
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The search core shared by :func:`kmeans` and the batched fold:
    cosine-normalize rows + multi-restart Lloyd → (xn, lowest-inertia
    centers). The final full-size assignment is the caller's — that's the
    kernel-servable hot-spot, single-entry or batched-grid alike."""
    x = x.astype(jnp.float32)
    # Normalize rows: the cluster signal is the gradient *direction* (the
    # magnitude mostly encodes confidence), cosine k-means is markedly more
    # robust here and is what "similar directions" in the paper implies.
    norms = jnp.linalg.norm(x, axis=1, keepdims=True)
    xn = x / jnp.maximum(norms, 1e-12)

    def one_run(k):
        centers = _kmeanspp_init(k, xn, num_clusters)

        def step(_, centers):
            # jnp path inside the vmapped restarts; only the final
            # full-size assignment is worth a kernel launch
            assign = assign_clusters(xn, centers, use_kernel=False)
            onehot = jax.nn.one_hot(assign, num_clusters, dtype=xn.dtype)  # (N, C)
            sums = onehot.T @ xn                                           # (C, d)
            counts = jnp.sum(onehot, axis=0)[:, None]
            new = sums / jnp.maximum(counts, 1.0)
            # keep empty clusters where they were
            new = jnp.where(counts > 0, new, centers)
            new = new / jnp.maximum(jnp.linalg.norm(new, axis=1, keepdims=True),
                                    1e-12)
            return new

        centers = jax.lax.fori_loop(0, num_iters, step, centers)
        inertia = jnp.sum(jnp.min(_pairwise_sq_dists(xn, centers), axis=1))
        return centers, inertia

    all_centers, inertias = jax.vmap(one_run)(jax.random.split(key, restarts))
    return xn, all_centers[jnp.argmin(inertias)]


@partial(jax.jit, static_argnames=("num_clusters", "num_iters", "use_kernel",
                                   "restarts"))
def kmeans(key, x: jnp.ndarray, num_clusters: int, num_iters: int = 25,
           use_kernel: bool = False, restarts: int = 4
           ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Multi-restart Lloyd; returns the lowest-inertia (assignments, centers)."""
    xn, centers = _normalized_search(key, x, num_clusters, num_iters, restarts)
    return assign_clusters(xn, centers, use_kernel=use_kernel), centers


def gradient_pseudo_labels(key, partial_grads: jnp.ndarray, num_classes: int,
                           num_iters: int = 25, use_kernel: bool = False,
                           restarts: int = 4) -> jnp.ndarray:
    """Ŷ_o^k ← k-means(∇_{H_o^k} Loss, C)   (Alg. 1, line 28).

    Fully jittable, so it also runs *inside* the engine's shard_map one-shot
    session (``repro.launch.vfl_step``) where it stays party-local — zero
    pod-axis collectives. ``restarts=1`` keeps that compiled path lean; the
    host-scale protocol keeps the default multi-restart robustness.
    Callers outside the engine should prefer ``repro.engine.pseudo_labels``,
    which carries the engine-wide ``use_kernels`` switch.
    """
    labels, _ = kmeans(key, partial_grads, num_classes, num_iters, use_kernel,
                       restarts=restarts)
    return labels


def gradient_pseudo_labels_batched(keys: jnp.ndarray,
                                   partial_grads: jnp.ndarray,
                                   num_classes: int, num_iters: int = 25,
                                   use_kernel: bool = False,
                                   restarts: int = 4) -> jnp.ndarray:
    """Step ③ for a stacked batch: keys (B, 2), partial_grads (B, N, d) →
    (B, N) pseudo labels.

    The batch axis is the engine's anonymous stacked fold axis (S seeds ×
    C scenarios × K parties upstream). The jnp route vmaps the single-entry
    program verbatim — bit-identical per entry to the per-call path. The
    kernel route vmaps only the center *search* and serves every entry's
    final full-size assignment with ONE batched ``(B, N/BN)`` Pallas grid
    (``repro.kernels.kmeans.ops.kmeans_assign_batched``) — no per-entry
    launch loop, no vmap-of-pallas_call. Callers wanting the session-cached
    compiled fold should go through ``repro.engine.pseudo_labels_batched``.
    """
    if not use_kernel:
        return jax.vmap(
            lambda k, g: gradient_pseudo_labels(
                k, g, num_classes, num_iters, use_kernel=False,
                restarts=restarts))(keys, partial_grads)
    xn, centers = jax.vmap(
        lambda k, g: _normalized_search(k, g, num_classes, num_iters,
                                        restarts))(keys, partial_grads)
    from repro.kernels.kmeans import ops as kops
    return kops.kmeans_assign_batched(xn, centers)


def cluster_purity(pseudo: jnp.ndarray, true: jnp.ndarray, num_classes: int) -> float:
    """Diagnostic: fraction of samples whose cluster's majority true-label
    matches their own (label-permutation-invariant accuracy upper bound)."""
    conf = jnp.zeros((num_classes, num_classes), jnp.int32)
    conf = conf.at[pseudo, true].add(1)
    return float(jnp.sum(jnp.max(conf, axis=1)) / pseudo.shape[0])


def align_pseudo_to_true(pseudo: jnp.ndarray, true: jnp.ndarray, num_classes: int
                         ) -> jnp.ndarray:
    """Greedy cluster→label matching (diagnostics only; clients cannot do
    this — they never see true labels)."""
    conf = jnp.zeros((num_classes, num_classes), jnp.int32).at[pseudo, true].add(1)
    conf = jnp.asarray(conf)
    import numpy as np

    conf = np.array(conf)
    mapping = -np.ones(num_classes, np.int32)
    used = set()
    for _ in range(num_classes):
        i, j = np.unravel_index(np.argmax(conf), conf.shape)
        mapping[i] = j
        conf[i, :] = -1
        conf[:, j] = -1
        used.add(j)
    # unassigned clusters (if any) map to remaining labels arbitrarily
    remaining = [j for j in range(num_classes) if j not in used]
    for i in range(num_classes):
        if mapping[i] < 0:
            mapping[i] = remaining.pop()
    return jnp.asarray(mapping)[pseudo]
