"""Few-shot VFL server-side machinery: representation estimation + gating.

* ``sdpa_transform`` — Eq. (10): Ĥ_u^B = softmax(H_u^A H_o^Aᵀ / √d) H_o^B.
  The jnp path is the oracle; ``use_kernel=True`` routes to the Pallas
  flash-style blocked kernel (repro.kernels.sdpa_estimator) which is the
  TPU hot-spot when N_u ≫ N_o.
* ``infer_prob`` — Eq. (8)-(9): agreement × confidence gating probability
  p̂_{u,i} for pseudo-labeling client unaligned samples.
"""
from __future__ import annotations

from typing import Callable, Sequence

import jax
import jax.numpy as jnp


def sdpa_transform(h_u_a: jnp.ndarray, h_o_a: jnp.ndarray, h_o_b: jnp.ndarray,
                   use_kernel: bool = False) -> jnp.ndarray:
    """Ĥ_u^B = softmax(H_u^A ⊗ H_o^Aᵀ / √d) ⊗ H_o^B    (Eq. 10).

    Shapes: h_u_a (N_u, d_a), h_o_a (N_o, d_a), h_o_b (N_o, d_b).
    """
    if use_kernel:
        from repro.kernels.sdpa_estimator import ops as kops
        return kops.sdpa_estimate(h_u_a, h_o_a, h_o_b)
    d = h_u_a.shape[-1]
    scores = (h_u_a @ h_o_a.T) / jnp.sqrt(jnp.asarray(d, h_u_a.dtype))
    return jax.nn.softmax(scores, axis=-1) @ h_o_b


def sdpa_transform_batched(h_u_a: jnp.ndarray, h_o_a: jnp.ndarray,
                           h_o_b: jnp.ndarray, use_kernel: bool = False
                           ) -> jnp.ndarray:
    """Eq. 10 over a stacked leading batch axis (the engine's anonymous
    fold axis: seeds, or a served partial-party batch).

    Shapes: h_u_a (B, N_u, d_a), h_o_a (B, N_o, d_a), h_o_b (B, N_o, d_b).
    The kernel route is ONE batched ``(B, N_u/BU, N_o/BO)`` Pallas grid
    launch; the jnp route vmaps the single-entry oracle verbatim."""
    if use_kernel:
        from repro.kernels.sdpa_estimator import ops as kops
        return kops.sdpa_estimate_batched(h_u_a, h_o_a, h_o_b)
    return jax.vmap(
        lambda q, a, b: sdpa_transform(q, a, b, use_kernel=False)
    )(h_u_a, h_o_a, h_o_b)


def estimate_missing_parties(
    h_u_k: jnp.ndarray,
    h_o_all: Sequence[jnp.ndarray],
    k: int,
    use_kernel: bool = False,
) -> list:
    """For client k's unaligned reps, estimate every other party's missing
    representation (K-ary generalization of Eq. 10, DESIGN.md §1)."""
    out = []
    for j, h_o_j in enumerate(h_o_all):
        if j == k:
            continue
        out.append(sdpa_transform(h_u_k, h_o_all[k], h_o_j, use_kernel=use_kernel))
    return out


def infer_prob(
    aux_logits_fn: Callable,      # (h_u_k,)            -> (N_u, C)  local-only f_c^k
    joint_logits_fn: Callable,    # (full_concat_rep,)  -> (N_u, C)  joint f_c
    h_u_k: jnp.ndarray,
    full_rep: jnp.ndarray,
    threshold: float,
) -> jnp.ndarray:
    """p̂_{u,i} = 1[ŷ^A = ŷ^{A,B}] · 1[p^A > t] · 1[p^{A,B} > t] · p^{A,B}  (Eq. 9)."""
    p_local = jax.nn.softmax(aux_logits_fn(h_u_k), axis=-1)
    p_joint = jax.nn.softmax(joint_logits_fn(full_rep), axis=-1)
    y_local = jnp.argmax(p_local, axis=-1)
    y_joint = jnp.argmax(p_joint, axis=-1)
    conf_local = jnp.max(p_local, axis=-1)
    conf_joint = jnp.max(p_joint, axis=-1)
    agree = (y_local == y_joint).astype(p_joint.dtype)
    gate = agree * (conf_local > threshold) * (conf_joint > threshold)
    return gate * conf_joint
