"""The paper's contribution: one-shot / few-shot VFL (Sun et al., 2023)."""
from repro.core.comm import CommLedger
from repro.core.protocol import (ProtocolConfig, VFLResult, run_few_shot,
                                 run_few_shot_finetune, run_one_shot,
                                 run_scenarios_seeds, run_seeds)
from repro.core.baselines import (IterativeConfig, run_fedbcd,
                                  run_fedbcd_seeds, run_fedcvt,
                                  run_fedcvt_seeds, run_vanilla,
                                  run_vanilla_seeds)
from repro.core.ssl import SSLConfig
from repro.core.runners import RUNNERS, RunnerEntry
from repro.core.rows import ResultRow, serving_row, training_row

__all__ = [
    "CommLedger",
    "RUNNERS",
    "RunnerEntry",
    "ResultRow",
    "training_row",
    "serving_row",
    "ProtocolConfig",
    "IterativeConfig",
    "SSLConfig",
    "VFLResult",
    "run_one_shot",
    "run_few_shot",
    "run_few_shot_finetune",
    "run_seeds",
    "run_scenarios_seeds",
    "run_vanilla",
    "run_vanilla_seeds",
    "run_fedbcd",
    "run_fedbcd_seeds",
    "run_fedcvt",
    "run_fedcvt_seeds",
]
