"""THE runner registry: one typed dispatch surface for every VFL method.

Before this module, the runner→seed-batched-impl mapping lived in
``core.protocol._batched_impls()`` and the method-name→runner mapping was
duplicated in ``benchmarks/frontier.py`` — two string/function tables that
could drift. Every dispatch site now resolves through :data:`RUNNERS`:

* ``run_seeds`` / ``run_scenarios_seeds`` look up the seed-batched impl
  (and the per-seed *state* kwargs the folded path must reject) via
  :func:`resolve`;
* ``benchmarks/frontier.py`` resolves its method names (including the
  ``"iterative"`` alias for vanilla SplitNN) via :func:`get`;
* the serving layer (``launch/vfl_serve``, ``benchmarks/serving.py``)
  consults ``servable`` before exporting a runner's result as a
  :class:`~repro.checkpoint.artifact.TrainedVFLModel`.

A :class:`RunnerEntry` is the method's full contract: the single-seed
runner (always the S = 1 case of the seed-batched impl), the impl itself,
which config family it takes (``ProtocolConfig`` vs ``IterativeConfig``),
the ledger policy (all current runners produce the prototype ledger ONCE
host-side; multi-seed orchestration copies it per result), the stateful
kwargs that cannot thread through a fold, and serving eligibility.
Unregistered runners still work everywhere — they take the per-seed
fallback loop with the default stateful-kwarg rejection.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, FrozenSet, List, Optional, Tuple, Union

from repro.core import baselines, protocol

# per-seed *state* kwargs: one live object cannot serve S folded seeds (and
# the heterogeneous-splits fallback loop cannot thread per-seed state)
STATE_KWARGS: FrozenSet[str] = frozenset(
    {"clients", "server", "ledger", "clients_per_seed", "servers"})

#: how the seed-batched impl produces ledgers — every current runner logs
#: host-side once ("prototype"); orchestration copies it per result
LEDGER_PROTOTYPE = "prototype"


@dataclass(frozen=True)
class RunnerEntry:
    """One method's dispatch contract (see module docstring)."""

    name: str                       # canonical method name
    runner: Callable                # single-seed entry (public API)
    seeds_impl: Callable            # seed-batched impl (DESIGN.md §10-11)
    kind: str                       # "protocol" | "iterative" (config family)
    ledger_policy: str = LEDGER_PROTOTYPE
    stateful_kwargs: FrozenSet[str] = STATE_KWARGS
    servable: bool = True           # result exports as a TrainedVFLModel
    aliases: Tuple[str, ...] = ()


_BY_NAME: Dict[str, RunnerEntry] = {}
_BY_RUNNER: Dict[Callable, RunnerEntry] = {}


def register(entry: RunnerEntry) -> RunnerEntry:
    for name in (entry.name,) + entry.aliases:
        if name in _BY_NAME:
            raise ValueError(f"runner name {name!r} already registered")
        _BY_NAME[name] = entry
    _BY_RUNNER[entry.runner] = entry
    return entry


def resolve(runner_or_name: Union[str, Callable]) -> Optional[RunnerEntry]:
    """The entry for a runner callable or method name; None when
    unregistered (callers then take the per-seed fallback loop)."""
    if isinstance(runner_or_name, str):
        return _BY_NAME.get(runner_or_name)
    return _BY_RUNNER.get(runner_or_name)


def get(name: str) -> RunnerEntry:
    """Like :func:`resolve` but by name only and raising on unknowns —
    what benchmark CLIs use so a typo'd method fails loudly."""
    entry = _BY_NAME.get(name)
    if entry is None:
        known = ", ".join(sorted(_BY_NAME))
        raise KeyError(f"unknown runner {name!r}; registered: {known}")
    return entry


def names(include_aliases: bool = False) -> List[str]:
    if include_aliases:
        return sorted(_BY_NAME)
    return sorted({e.name for e in _BY_NAME.values()})


def reject_stateful_kwargs(entry_label: str, runner_kwargs: dict,
                           entry: Optional[RunnerEntry] = None) -> None:
    """Refuse per-seed state kwargs at the multi-seed entries. The reject
    list is the registry entry's ``stateful_kwargs`` attribute (the default
    :data:`STATE_KWARGS` for unregistered runners)."""
    banned = entry.stateful_kwargs if entry is not None else STATE_KWARGS
    stateful = sorted(banned & set(runner_kwargs))
    if stateful:
        raise ValueError(
            f"{entry_label} does not accept per-seed state kwargs "
            f"{stateful}: one object cannot serve every seed (and the "
            f"heterogeneous-splits fallback loop cannot thread per-seed "
            f"state) — call the runner or its *_seeds entry directly "
            f"instead")


# ---------------------------------------------------------------- catalog
RUNNERS: Tuple[RunnerEntry, ...] = tuple(register(e) for e in (
    RunnerEntry("one_shot", protocol.run_one_shot,
                protocol._one_shot_seeds, kind="protocol"),
    RunnerEntry("few_shot", protocol.run_few_shot,
                protocol._few_shot_seeds, kind="protocol"),
    RunnerEntry("few_shot_finetune", protocol.run_few_shot_finetune,
                protocol._few_shot_finetune_seeds, kind="protocol"),
    RunnerEntry("vanilla", baselines.run_vanilla,
                baselines.run_vanilla_seeds, kind="iterative",
                aliases=("iterative",)),
    RunnerEntry("fedcvt", baselines.run_fedcvt,
                baselines.run_fedcvt_seeds, kind="iterative"),
    RunnerEntry("fedbcd", baselines.run_fedbcd,
                baselines.run_fedbcd_seeds, kind="iterative"),
))
