"""VFL server: label holder, partial-gradient computation, classifier training.

Steps ②/⑥ of one-shot VFL and the auxiliary/joint classifier fitting of
few-shot VFL (Alg. 2 lines 2-4) live here. The server owns Y_o and θ_c and
never ships either to clients — only ∇_{H_o^k} L, C, and p̂.

Classifier fits (``_fit``) run as ONE jitted ``lax.scan`` session over a
precomputed epoch×minibatch schedule, cached in the engine-wide session
cache (``engine.sessions``, domain ``"server_fit"``) on the semantic model
identity + optimizer hyper-parameters. A few-shot run performs K aux fits
plus three joint fits; a 15-scenario × seeds sweep used to re-trace a fresh
``jax.jit`` step for every single one — now each distinct (arch, shapes,
epochs, bs, lr) combination compiles exactly once per process
(DESIGN.md §9). The protocol's seed-batched runs go through
``train_classifier_seeds`` / ``fit_aux_classifiers_seeds``, which vmap the
same session over a seed axis (DESIGN.md §10) with per-seed key/schedule
discipline identical to the methods'.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.ssl import cross_entropy
from repro.data.loader import epoch_batches
from repro.engine import sessions
from repro.models.extractors import Model, make_classifier


def concat_reps(reps: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """h^1 ∘ … ∘ h^K (Eq. 2)."""
    return jnp.concatenate(list(reps), axis=-1)


@dataclass
class VFLServer:
    num_classes: int
    classifier: Model = None            # joint f_c
    params: Any = None
    aux_classifiers: List[Model] = field(default_factory=list)   # f_c^k
    aux_params: List[Any] = field(default_factory=list)

    # -------------------------------------------------- step ②: partial grads
    def partial_gradients(self, key: jax.Array, reps: Sequence[jnp.ndarray],
                          labels: jnp.ndarray) -> List[jnp.ndarray]:
        """∇_{H_o^k} g(f_c(H¹∘…∘H^K), Y_o) for every k (Alg. 1 line 6).

        Initializes θ_c lazily on first call (the paper computes the partial
        gradients with the freshly initialized classifier)."""
        h = concat_reps(reps)
        if self.params is None:
            self.classifier = make_classifier(self.num_classes)
            self.params = self.classifier.init(key, h)

        def loss_of_reps(parts):
            logits = self.classifier.apply(self.params, concat_reps(parts))
            return jnp.mean(cross_entropy(logits, labels))

        grads = jax.grad(loss_of_reps)(list(reps))
        return list(grads)

    # ------------------------------------------------ step ⑥: train classifier
    def train_classifier(self, key: jax.Array, reps: Sequence[jnp.ndarray],
                         labels: jnp.ndarray, epochs: int = 50,
                         batch_size: int = 32, learning_rate: float = 0.01):
        h = concat_reps(reps)
        if self.classifier is None:
            self.classifier = make_classifier(self.num_classes)
        key, k0 = jax.random.split(key)
        self.params = self.classifier.init(k0, h)   # re-fit on fresh reps
        self.params = _fit(key, self.classifier, self.params, h, labels,
                           epochs, batch_size, learning_rate)
        return self

    # ----------------------------------- few-shot: aux + joint classifiers (②')
    def fit_aux_classifiers(self, key: jax.Array, reps: Sequence[jnp.ndarray],
                            labels: jnp.ndarray, epochs: int = 50,
                            batch_size: int = 32, learning_rate: float = 0.01):
        """θ_c^k ← argmin g(f_c^k(H_o^k), Y_o)  (Alg. 2 line 2)."""
        self.aux_classifiers, self.aux_params = [], []
        for k_idx, h in enumerate(reps):
            key, k0, k1 = jax.random.split(key, 3)
            clf = make_classifier(self.num_classes)
            p = clf.init(k0, h)
            p = _fit(k1, clf, p, h, labels, epochs, batch_size, learning_rate)
            self.aux_classifiers.append(clf)
            self.aux_params.append(p)
        return self

    def aux_logits_fn(self, k: int) -> Callable:
        clf, p = self.aux_classifiers[k], self.aux_params[k]
        return lambda h: clf.apply(p, h)

    def joint_logits_fn(self) -> Callable:
        return lambda h: self.classifier.apply(self.params, h)

    # --------------------------------------------------------------- predict
    def predict_logits(self, reps: Sequence[jnp.ndarray]) -> jnp.ndarray:
        return self.classifier.apply(self.params, concat_reps(reps))


def _fit_schedule(key, n: int, epochs: int, batch_size: int):
    """The fit's epoch×minibatch schedule (shuffled epochs, drop-remainder —
    identical batches to the historical Python loop), materialized host-side
    so it travels as an argument. ``None`` means a no-op fit (epochs == 0,
    or n < batch_size with drop-remainder)."""
    bs = min(batch_size, n)
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    rows = [idx for e in range(epochs) for idx in epoch_batches(n, bs, seed0 + e)]
    if not rows:
        return None
    return jnp.asarray(np.stack(rows), jnp.int32)


def _fit_session(model: Model, lr):
    """The whole-classifier-fit ``lax.scan`` session as a pure function of
    (params, x, y, schedule). ``_fit`` jits and caches it; the seed-batched
    path (``engine.batched.fit_sessions_batched``) vmaps it over a leading
    batch axis — both against the same session cache domain."""
    tx = optim.chain(optim.clip_by_global_norm(5.0),
                     optim.sgd(lr, momentum=0.9))

    def session(params, x, y, schedule):
        opt_state = tx.init(params)

        def body(carry, idx):
            p, o = carry

            def loss_fn(p_):
                return jnp.mean(cross_entropy(model.apply(p_, x[idx]),
                                              y[idx]))

            loss, grads = jax.value_and_grad(loss_fn)(p)
            updates, o = tx.update(grads, o, p)
            return (optim.apply_updates(p, updates), o), loss

        (params, _), _ = jax.lax.scan(body, (params, opt_state), schedule)
        return params

    return session


def _fit(key, model: Model, params, x, y, epochs, batch_size, lr):
    """Whole classifier fit as one cached, jitted ``lax.scan`` session.

    Params/data/schedule travel as arguments so the compiled session is
    reusable across seeds and scenario points of equal shapes."""
    schedule = _fit_schedule(key, x.shape[0], epochs, batch_size)
    if schedule is None:
        return params
    fit = sessions.cached_session(
        "server_fit", (sessions.model_key(model), float(lr)),
        lambda: jax.jit(_fit_session(model, lr), donate_argnums=(0,)))
    return fit(params, x, y, schedule)


# ------------------------------------------------- seed-batched server fits
def train_classifier_seeds(keys, servers: Sequence[VFLServer],
                           reps_per_seed, labels_per_seed,
                           epochs: int = 50, batch_size: int = 32,
                           learning_rate: float = 0.01, mesh=None):
    """Seed-batched :meth:`VFLServer.train_classifier`: per-seed key and
    schedule discipline identical to the method (so a multi-seed run matches
    a Python loop of single-seed runs), but every seed's fit executes inside
    ONE vmapped scan session (DESIGN.md §10)."""
    from repro.engine import batched   # deferred: engine init imports core

    hs = [concat_reps(r) for r in reps_per_seed]
    params, scheds = [], []
    for key, srv, h in zip(keys, servers, hs):
        if srv.classifier is None:
            srv.classifier = make_classifier(srv.num_classes)
        key, k0 = jax.random.split(key)
        params.append(srv.classifier.init(k0, h))
        scheds.append(_fit_schedule(key, h.shape[0], epochs, batch_size))
    mk0 = sessions.model_key(servers[0].classifier)
    assert all(sessions.model_key(s.classifier) == mk0 for s in servers[1:]), \
        "seed-batched classifier fit requires semantically equal classifiers"
    if any(sc is None for sc in scheds):         # no-op fits are all-or-none
        assert all(sc is None for sc in scheds)  # (equal n/epochs per seed)
        fitted = params
    else:
        fitted = batched.fit_sessions_batched(
            servers[0].classifier, learning_rate, params, hs,
            labels_per_seed, scheds, mesh=mesh)
    for srv, p in zip(servers, fitted):
        srv.params = p
    return servers


def fit_aux_classifiers_seeds(keys, servers: Sequence[VFLServer],
                              reps_per_seed, labels_per_seed,
                              epochs: int = 50, batch_size: int = 32,
                              learning_rate: float = 0.01, mesh=None):
    """Seed-batched :meth:`VFLServer.fit_aux_classifiers`: for each party,
    every seed's aux fit folds into one vmapped scan session. All fits of
    one architecture × learning rate share a single cached program with the
    joint-classifier fits (domain ``"server_fit"``)."""
    from repro.engine import batched   # deferred: engine init imports core

    keys = list(keys)
    for srv in servers:
        srv.aux_classifiers, srv.aux_params = [], []
    num_parties = len(reps_per_seed[0])
    for k_idx in range(num_parties):
        params, hs, scheds, clfs = [], [], [], []
        for s, srv in enumerate(servers):
            h = reps_per_seed[s][k_idx]
            keys[s], k0, k1 = jax.random.split(keys[s], 3)
            clf = make_classifier(srv.num_classes)
            clfs.append(clf)
            hs.append(h)
            params.append(clf.init(k0, h))
            scheds.append(_fit_schedule(k1, h.shape[0], epochs, batch_size))
        if any(sc is None for sc in scheds):
            assert all(sc is None for sc in scheds)
            fitted = params
        else:
            fitted = batched.fit_sessions_batched(
                clfs[0], learning_rate, params, hs, labels_per_seed, scheds,
                mesh=mesh)
        for srv, clf, p in zip(servers, clfs, fitted):
            srv.aux_classifiers.append(clf)
            srv.aux_params.append(p)
    return servers
