"""VFL server: label holder, partial-gradient computation, classifier training.

Steps ②/⑥ of one-shot VFL and the auxiliary/joint classifier fitting of
few-shot VFL (Alg. 2 lines 2-4) live here. The server owns Y_o and θ_c and
never ships either to clients — only ∇_{H_o^k} L, C, and p̂.

Classifier fits (``_fit``) run as ONE jitted ``lax.scan`` session over a
precomputed epoch×minibatch schedule, cached in the engine-wide session
cache (``engine.sessions``, domain ``"server_fit"``) on the semantic model
identity + optimizer hyper-parameters. A few-shot run performs K aux fits
plus three joint fits; a 15-scenario × seeds sweep used to re-trace a fresh
``jax.jit`` step for every single one — now each distinct (arch, shapes,
epochs, bs, lr) combination compiles exactly once per process
(DESIGN.md §9).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.ssl import cross_entropy
from repro.data.loader import epoch_batches
from repro.engine import sessions
from repro.models.extractors import Model, make_classifier


def concat_reps(reps: Sequence[jnp.ndarray]) -> jnp.ndarray:
    """h^1 ∘ … ∘ h^K (Eq. 2)."""
    return jnp.concatenate(list(reps), axis=-1)


@dataclass
class VFLServer:
    num_classes: int
    classifier: Model = None            # joint f_c
    params: Any = None
    aux_classifiers: List[Model] = field(default_factory=list)   # f_c^k
    aux_params: List[Any] = field(default_factory=list)

    # -------------------------------------------------- step ②: partial grads
    def partial_gradients(self, key: jax.Array, reps: Sequence[jnp.ndarray],
                          labels: jnp.ndarray) -> List[jnp.ndarray]:
        """∇_{H_o^k} g(f_c(H¹∘…∘H^K), Y_o) for every k (Alg. 1 line 6).

        Initializes θ_c lazily on first call (the paper computes the partial
        gradients with the freshly initialized classifier)."""
        h = concat_reps(reps)
        if self.params is None:
            self.classifier = make_classifier(self.num_classes)
            self.params = self.classifier.init(key, h)

        def loss_of_reps(parts):
            logits = self.classifier.apply(self.params, concat_reps(parts))
            return jnp.mean(cross_entropy(logits, labels))

        grads = jax.grad(loss_of_reps)(list(reps))
        return list(grads)

    # ------------------------------------------------ step ⑥: train classifier
    def train_classifier(self, key: jax.Array, reps: Sequence[jnp.ndarray],
                         labels: jnp.ndarray, epochs: int = 50,
                         batch_size: int = 32, learning_rate: float = 0.01):
        h = concat_reps(reps)
        if self.classifier is None:
            self.classifier = make_classifier(self.num_classes)
        key, k0 = jax.random.split(key)
        self.params = self.classifier.init(k0, h)   # re-fit on fresh reps
        self.params = _fit(key, self.classifier, self.params, h, labels,
                           epochs, batch_size, learning_rate)
        return self

    # ----------------------------------- few-shot: aux + joint classifiers (②')
    def fit_aux_classifiers(self, key: jax.Array, reps: Sequence[jnp.ndarray],
                            labels: jnp.ndarray, epochs: int = 50,
                            batch_size: int = 32, learning_rate: float = 0.01):
        """θ_c^k ← argmin g(f_c^k(H_o^k), Y_o)  (Alg. 2 line 2)."""
        self.aux_classifiers, self.aux_params = [], []
        for k_idx, h in enumerate(reps):
            key, k0, k1 = jax.random.split(key, 3)
            clf = make_classifier(self.num_classes)
            p = clf.init(k0, h)
            p = _fit(k1, clf, p, h, labels, epochs, batch_size, learning_rate)
            self.aux_classifiers.append(clf)
            self.aux_params.append(p)
        return self

    def aux_logits_fn(self, k: int) -> Callable:
        clf, p = self.aux_classifiers[k], self.aux_params[k]
        return lambda h: clf.apply(p, h)

    def joint_logits_fn(self) -> Callable:
        return lambda h: self.classifier.apply(self.params, h)

    # --------------------------------------------------------------- predict
    def predict_logits(self, reps: Sequence[jnp.ndarray]) -> jnp.ndarray:
        return self.classifier.apply(self.params, concat_reps(reps))


def _fit(key, model: Model, params, x, y, epochs, batch_size, lr):
    """Whole classifier fit as one cached, jitted ``lax.scan`` session.

    The schedule (shuffled epochs, drop-remainder — identical batches to
    the historical Python loop) is materialized up front; params/data/
    schedule travel as arguments so the compiled session is reusable
    across seeds and scenario points of equal shapes."""
    n = x.shape[0]
    bs = min(batch_size, n)
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    rows = [idx for e in range(epochs) for idx in epoch_batches(n, bs, seed0 + e)]
    if not rows:                                 # epochs == 0 (or n < bs with
        return params                            # drop-remainder): no-op fit
    schedule = jnp.asarray(np.stack(rows), jnp.int32)

    def build():
        tx = optim.chain(optim.clip_by_global_norm(5.0),
                         optim.sgd(lr, momentum=0.9))

        def session(params, x, y, schedule):
            opt_state = tx.init(params)

            def body(carry, idx):
                p, o = carry

                def loss_fn(p_):
                    return jnp.mean(cross_entropy(model.apply(p_, x[idx]),
                                                  y[idx]))

                loss, grads = jax.value_and_grad(loss_fn)(p)
                updates, o = tx.update(grads, o, p)
                return (optim.apply_updates(p, updates), o), loss

            (params, _), _ = jax.lax.scan(body, (params, opt_state), schedule)
            return params

        return jax.jit(session, donate_argnums=(0,))

    fit = sessions.cached_session(
        "server_fit", (sessions.model_key(model), float(lr)), build)
    return fit(params, x, y, schedule)
