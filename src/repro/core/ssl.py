"""Local semi-supervised learning (step ④): FixMatch and FixMatch-tab.

Implements the abstract objective of Eq. (4)

    l_ssl(θ; X_u, X_o, Ŷ_o) = l_s(θ; X_o, Ŷ_o) + λ_u · l_u(θ; X_u)

with FixMatch's pseudo-label-with-confidence-threshold form of l_u:
    q = p(y | α(x_u));  l_u = 1[max q > τ] · CE(p(y | A(x_u)), argmax q)

Modality dispatch picks the paper's augmentations: image (flip/translate/
cutout/jitter) or tabular (Eq. 5-6 feature masking + noise). "feature"
modality = tabular augs applied to any flat feature vector (used when the
extractor is an LM/SSM backbone over embeddings — DESIGN.md §4).

``ssl_loss`` is consumed exclusively through the engine layer's
``repro.engine.make_ssl_step_fn`` (DESIGN.md §2), which wraps one minibatch
of this objective plus the optimizer update into the step function shared
by the host-scale protocol and the multi-pod shard_map schedule.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import augment


@dataclass(frozen=True)
class SSLConfig:
    modality: str = "image"          # "image" | "tabular" | "token"
    lambda_u: float = 1.0            # λ_u in Eq. (4)
    confidence_threshold: float = 0.95   # τ (FixMatch default)
    mask_ratio: float = 0.2          # r_m (paper: 0.2)
    sigma: float = 0.1               # σ   (paper: 0.1)
    max_shift: int = 4
    cutout_size: int = 8


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]


def _augment_pair(key, x, cfg: SSLConfig, feature_mean):
    """Return (weak, strong) views for the configured modality."""
    if cfg.modality == "image":
        kw, ks = jax.random.split(key)
        return (augment.weak_augment_image(kw, x, cfg.max_shift),
                augment.strong_augment_image(ks, x, cfg.max_shift, cfg.cutout_size))
    if cfg.modality == "token":
        return augment.token_augment_pair(key, x, mask_ratio=cfg.mask_ratio)
    return augment.tab_augment_pair(key, x, feature_mean, cfg.mask_ratio, cfg.sigma)


def ssl_loss(
    logits_fn: Callable,          # (params, x) -> (B, C)
    params,
    key: jax.Array,
    x_labeled: jnp.ndarray,
    y_labeled: jnp.ndarray,
    x_unlabeled: jnp.ndarray,
    cfg: SSLConfig,
    feature_mean: Optional[jnp.ndarray] = None,
    labeled_mask: Optional[jnp.ndarray] = None,
    unlabeled_mask: Optional[jnp.ndarray] = None,
) -> Tuple[jnp.ndarray, dict]:
    """One minibatch of Eq. (4). Returns (loss, metrics).

    ``labeled_mask`` / ``unlabeled_mask`` are per-row validity masks for the
    masked fixed-shape sessions of DESIGN.md §9: few-shot phase ⑤' pads every
    party's gated labeled set to the static capacity N_o + N_u and keeps the
    full private pool as the unlabeled set, so padded labeled rows and
    gated-out (or exhausted) unlabeled rows must contribute exactly zero
    loss. ``None`` (the default) means every row is valid and reproduces the
    unmasked objective bit-for-bit.
    """
    k_l, k_u = jax.random.split(key)

    # -- supervised term on (weakly augmented) labeled data ------------------
    if cfg.modality == "image":
        xl = augment.weak_augment_image(k_l, x_labeled, cfg.max_shift)
    elif cfg.modality == "token":
        xl = augment.weak_augment_tokens(k_l, x_labeled, mask_ratio=cfg.mask_ratio)
    else:
        xl = augment.weak_augment_tab(k_l, x_labeled, feature_mean, cfg.mask_ratio)
    ce_l = cross_entropy(logits_fn(params, xl), y_labeled)
    if labeled_mask is None:
        l_s = jnp.mean(ce_l)
    else:
        m_l = labeled_mask.astype(ce_l.dtype)
        l_s = jnp.sum(ce_l * m_l) / jnp.maximum(jnp.sum(m_l), 1.0)

    # -- unsupervised FixMatch term ------------------------------------------
    weak_u, strong_u = _augment_pair(k_u, x_unlabeled, cfg, feature_mean)
    q = jax.nn.softmax(logits_fn(params, weak_u), axis=-1)
    q = jax.lax.stop_gradient(q)
    pseudo = jnp.argmax(q, axis=-1)
    conf = jnp.max(q, axis=-1)
    mask = (conf > cfg.confidence_threshold).astype(jnp.float32)
    if unlabeled_mask is not None:
        mask = mask * unlabeled_mask.astype(mask.dtype)
    ce_u = cross_entropy(logits_fn(params, strong_u), pseudo)
    l_u = jnp.sum(ce_u * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    loss = l_s + cfg.lambda_u * l_u
    # static-shape guard: a zero-row unlabeled batch (full-overlap party,
    # empty private pool) must report rate 0, not the NaN of an empty mean
    metrics = {
        "loss": loss, "l_s": l_s, "l_u": l_u,
        "pseudo_mask_rate": jnp.sum(mask) / max(mask.shape[0], 1),
    }
    return loss, metrics
