"""One typed result-row schema for every benchmark surface (DESIGN.md §13).

``VFLResult.summary_row()``, the frontier's per-(scenario, method, seed)
rows, and the serving benchmark's per-batch-size rows used to be three
hand-rolled dict shapes; ``check_gate`` and the serving gate each parsed
their own. They are now all built by :func:`training_row` /
:func:`serving_row` over ONE :class:`ResultRow` core — so every gate
consumes the same shape and a field added in one place shows up (or fails
loudly) everywhere.

Schema: every row carries the typed core

    kind         "train" | "serving"
    metric_name  what ``metric`` measures ("auc", "accuracy", "p50_ms", …)
    metric       the headline scalar (gates compare THIS field)

training rows add the paper's communication columns (``comm_bytes``,
``comm_times``) and the whitelisted execution diagnostics
(:data:`DIAGNOSTIC_KEYS`); serving rows add latency/throughput context.
Free-form ``context`` keys flatten into the emitted dict but may never
shadow a core key — collisions raise instead of silently clobbering a
gated field.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Mapping, Optional

KINDS = ("train", "serving")

#: execution diagnostics a training row forwards from ``VFLResult``
DIAGNOSTIC_KEYS = ("iterations", "engine_path", "seed_fold", "scenario_fold",
                   "device_fold", "kernel_fold", "kernel_fallback",
                   "sdpa_fold",
                   # fault-injection diagnostics (DESIGN.md §16)
                   "parties_survived", "fault_kind", "fault_stage",
                   "degraded_metric", "fault_retry_rounds",
                   "fault_retry_bytes", "fault_modeled")

CORE_KEYS = ("kind", "metric_name", "metric", "comm_bytes", "comm_times")


@dataclass(frozen=True)
class ResultRow:
    """The typed row core every benchmark surface serializes through."""

    kind: str
    metric_name: str
    metric: float
    comm_bytes: Optional[int] = None
    comm_times: Optional[int] = None
    context: Mapping[str, Any] = field(default_factory=dict)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"row kind {self.kind!r} not in {KINDS}")
        clash = sorted(set(self.context) & set(CORE_KEYS))
        if clash:
            raise ValueError(f"context keys {clash} would shadow typed row "
                             f"fields — rename them")

    def as_dict(self) -> Dict[str, Any]:
        row: Dict[str, Any] = {"kind": self.kind,
                               "metric_name": self.metric_name,
                               "metric": float(self.metric)}
        if self.comm_bytes is not None:
            row["comm_bytes"] = int(self.comm_bytes)
        if self.comm_times is not None:
            row["comm_times"] = int(self.comm_times)
        row.update(self.context)
        return row


def training_row(result, **context) -> Dict[str, Any]:
    """The JSON-ready summary of one training result (the paper's three
    columns: metric, comm bytes, comm times) plus whitelisted diagnostics
    and caller context. ``result`` is any ``VFLResult``-shaped object."""
    diags = {k: result.diagnostics[k] for k in DIAGNOSTIC_KEYS
             if k in result.diagnostics}
    clash = sorted(set(diags) & set(context))
    if clash:
        raise ValueError(f"context keys {clash} collide with forwarded "
                         f"diagnostics")
    return ResultRow(
        kind="train",
        metric_name=result.metric_name,
        metric=float(result.metric),
        comm_bytes=int(result.ledger.total_bytes()),
        comm_times=int(result.ledger.comm_times()),
        context={**diags, **context},
    ).as_dict()


def serving_row(metric_name: str, metric: float, **context) -> Dict[str, Any]:
    """One serving-benchmark row (``metric`` is the gated headline — e.g.
    p50 latency in ms); batch size, throughput, parity, and cache counters
    travel as context."""
    return ResultRow(kind="serving", metric_name=metric_name,
                     metric=float(metric), context=context).as_dict()
