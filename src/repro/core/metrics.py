"""Utility metrics: accuracy and AUC (rank-based, no sklearn)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def accuracy(logits: jnp.ndarray, labels: jnp.ndarray) -> float:
    return float(jnp.mean((jnp.argmax(logits, axis=-1) == labels)))


def binary_auc(scores, labels) -> float:
    """Mann-Whitney AUC with tie correction via average ranks (numpy)."""
    s = np.asarray(scores, np.float64)
    labels_np = np.asarray(labels)
    order = np.argsort(s)
    sorted_s = s[order]
    r = np.arange(1, len(s) + 1, dtype=np.float64)
    uniq, inv, counts = np.unique(sorted_s, return_inverse=True, return_counts=True)
    sums = np.zeros(len(uniq))
    np.add.at(sums, inv, r)
    mean_ranks = sums / counts
    ranks = np.empty(len(s))
    ranks[order] = mean_ranks[inv]
    n_pos = int(labels_np.sum())
    n_neg = len(labels_np) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[labels_np == 1].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))
