"""Communication ledger — the paper's two efficiency metrics.

The paper reports, per method (Tab. 1 / Fig. 6-7):
  * ``comm times`` — the number of upload/download events a client performs
    over the whole training session (vanilla VFL: 2 per iteration; one-shot
    VFL: 3 total = upload reps, download grads, upload reps);
  * ``comm cost``  — total bytes moved between clients and server.

Every protocol phase in ``repro.core`` logs through a ``CommLedger`` so the
benchmark tables are produced by the *same code path* as training, not by a
separate analytic formula (the analytic formula is kept as a cross-check in
``benchmarks/comm_cost.py``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Tuple

import numpy as np


def nbytes(x) -> int:
    """Size in bytes of an array or pytree of arrays."""
    import jax

    leaves = jax.tree_util.tree_leaves(x)
    total = 0
    for leaf in leaves:
        total += int(np.prod(np.shape(leaf))) * np.dtype(leaf.dtype).itemsize
    return total


@dataclass
class CommEvent:
    party: int          # client index (server side of the link is implicit)
    direction: str      # "up" (client->server) or "down" (server->client)
    tag: str            # e.g. "reps_overlap", "partial_grads"
    bytes: int
    round: int = -1     # payloads sharing a round id travel in one message


@dataclass
class CommLedger:
    events: List[CommEvent] = field(default_factory=list)
    _round_counter: int = 0

    def next_round(self) -> int:
        self._round_counter += 1
        return self._round_counter

    def log(self, party: int, direction: str, tag: str, payload,
            round: int | None = None) -> None:
        assert direction in ("up", "down"), direction
        if round is None:
            round = self.next_round()
        self.events.append(CommEvent(party, direction, tag, nbytes(payload), round))

    def log_bytes(self, party: int, direction: str, tag: str, num_bytes: int,
                  round: int | None = None) -> None:
        assert direction in ("up", "down"), direction
        if round is None:
            round = self.next_round()
        self.events.append(CommEvent(party, direction, tag, int(num_bytes), round))

    # -- the paper's metrics ------------------------------------------------
    def total_bytes(self) -> int:
        return sum(e.bytes for e in self.events)

    def total_megabytes(self) -> float:
        return self.total_bytes() / 2**20

    def comm_times(self, party: int | None = None) -> int:
        """Number of distinct communication rounds a client participates in
        (payloads bundled in the same message — same round id — count once).
        Without a party argument: max over parties (the session is gated by
        the busiest client)."""
        if party is not None:
            return len({e.round for e in self.events if e.party == party})
        parties = {e.party for e in self.events}
        if not parties:
            return 0
        return max(len({e.round for e in self.events if e.party == p})
                   for p in parties)

    def by_tag(self) -> Dict[str, Tuple[int, int]]:
        out: Dict[str, Tuple[int, int]] = {}
        for e in self.events:
            cnt, byt = out.get(e.tag, (0, 0))
            out[e.tag] = (cnt + 1, byt + e.bytes)
        return out

    def summary(self) -> str:
        lines = [f"total: {self.total_megabytes():.2f} MB over "
                 f"{self.comm_times()} comm times (busiest client)"]
        for tag, (cnt, byt) in sorted(self.by_tag().items()):
            lines.append(f"  {tag:24s} x{cnt:<6d} {byt / 2**20:9.3f} MB")
        return "\n".join(lines)
