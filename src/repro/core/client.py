"""VFL client: representation extractor + local classification head + SSL.

The client never sees true labels. Its local model is (extractor f_k → local
head), trained by semi-supervised learning on gradient-clustering
pseudo-labels (one-shot, Alg. 1 l.28-34) optionally expanded with the
server-gated pseudo-labeled unaligned samples (few-shot, Alg. 2 l.11-19).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.ssl import SSLConfig
from repro.engine.local_ssl import (PartyParams, PartyTask, SSLHParams,
                                    train_party_ssl)
from repro.models.extractors import Model, make_classifier

# The (extractor, head) parameter pair is defined by the engine layer so the
# protocol path and the multi-pod schedule train the same structure.
ClientParams = PartyParams


@dataclass
class VFLClient:
    index: int
    extractor: Model
    head: Model
    params: ClientParams
    ssl_cfg: SSLConfig
    feature_mean: Optional[jnp.ndarray]   # x̄ for FixMatch-tab

    # ------------------------------------------------------------------ api
    def extract(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.extractor.apply(self.params.extractor, x)

    def local_logits(self, x: jnp.ndarray) -> jnp.ndarray:
        reps = self.extractor.apply(self.params.extractor, x)
        return self.head.apply(self.params.head, reps)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.argmax(self.local_logits(x), axis=-1)


def make_client(key: jax.Array, index: int, extractor: Model, num_classes: int,
                sample_input: jnp.ndarray, ssl_cfg: SSLConfig,
                local_data_for_mean: Optional[jnp.ndarray] = None) -> VFLClient:
    k_e, k_h = jax.random.split(key)
    e_params = extractor.init(k_e, sample_input)
    head = make_classifier(num_classes)
    reps = extractor.apply(e_params, sample_input[:1])
    h_params = head.init(k_h, reps)
    fm = None
    if (local_data_for_mean is not None and local_data_for_mean.ndim == 2
            and local_data_for_mean.shape[0] > 0):   # empty pool ⇒ NaN mean
        fm = jnp.mean(local_data_for_mean, axis=0)
    return VFLClient(index=index, extractor=extractor, head=head,
                     params=ClientParams(e_params, h_params),
                     ssl_cfg=ssl_cfg, feature_mean=fm)


# ----------------------------------------------------------------- SSL loop
def ssl_task_for(client: VFLClient, x_labeled: jnp.ndarray,
                 y_pseudo: jnp.ndarray, x_unlabeled: jnp.ndarray,
                 labeled_mask: Optional[jnp.ndarray] = None,
                 unlabeled_mask: Optional[jnp.ndarray] = None,
                 step_valid: Optional[jnp.ndarray] = None) -> PartyTask:
    """Package this client's local-SSL problem for the engine layer.

    Pass ``labeled_mask`` / ``unlabeled_mask`` for the masked fixed-shape
    sessions of few-shot phase ⑤' (data padded to a static capacity; masked
    rows contribute zero loss — DESIGN.md §9), ``step_valid`` for faulted
    sessions (per-step commit mask — stragglers, dropped or
    representation-only parties; DESIGN.md §16)."""
    return PartyTask(extractor=client.extractor, head=client.head,
                     params=PartyParams(*client.params),
                     ssl_cfg=client.ssl_cfg,
                     x_labeled=x_labeled, y_pseudo=y_pseudo,
                     x_unlabeled=x_unlabeled,
                     feature_mean=client.feature_mean,
                     labeled_mask=labeled_mask,
                     unlabeled_mask=unlabeled_mask,
                     step_valid=step_valid)


def local_ssl_train(
    key: jax.Array,
    client: VFLClient,
    x_labeled: jnp.ndarray,
    y_pseudo: jnp.ndarray,
    x_unlabeled: jnp.ndarray,
    epochs: int,
    batch_size: int = 32,
    learning_rate: float = 0.01,
    momentum: float = 0.9,
    unlabeled_ratio: int = 2,
) -> Tuple[VFLClient, dict]:
    """Alg. 1 lines 29-34: epochs of minibatch SSL. Labeled and unlabeled
    minibatches are drawn independently (FixMatch uses μ=unlabeled_ratio×
    larger unlabeled batches). Thin wrapper over the engine's single-party
    path; ``repro.core.protocol`` batches all parties through the engine's
    vmap fast path instead of calling this per client."""
    hp = SSLHParams(epochs=epochs, batch_size=batch_size,
                    learning_rate=learning_rate, momentum=momentum,
                    unlabeled_ratio=unlabeled_ratio)
    params, metrics = train_party_ssl(
        key, ssl_task_for(client, x_labeled, y_pseudo, x_unlabeled), hp)
    return replace(client, params=ClientParams(*params)), metrics
