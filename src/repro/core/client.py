"""VFL client: representation extractor + local classification head + SSL.

The client never sees true labels. Its local model is (extractor f_k → local
head), trained by semi-supervised learning on gradient-clustering
pseudo-labels (one-shot, Alg. 1 l.28-34) optionally expanded with the
server-gated pseudo-labeled unaligned samples (few-shot, Alg. 2 l.11-19).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from functools import partial
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.ssl import SSLConfig, ssl_loss
from repro.data.loader import epoch_batches
from repro.models.extractors import Model, make_classifier


class ClientParams(NamedTuple):
    extractor: Any
    head: Any


@dataclass
class VFLClient:
    index: int
    extractor: Model
    head: Model
    params: ClientParams
    ssl_cfg: SSLConfig
    feature_mean: Optional[jnp.ndarray]   # x̄ for FixMatch-tab

    # ------------------------------------------------------------------ api
    def extract(self, x: jnp.ndarray) -> jnp.ndarray:
        return self.extractor.apply(self.params.extractor, x)

    def local_logits(self, x: jnp.ndarray) -> jnp.ndarray:
        reps = self.extractor.apply(self.params.extractor, x)
        return self.head.apply(self.params.head, reps)

    def predict(self, x: jnp.ndarray) -> jnp.ndarray:
        return jnp.argmax(self.local_logits(x), axis=-1)


def make_client(key: jax.Array, index: int, extractor: Model, num_classes: int,
                sample_input: jnp.ndarray, ssl_cfg: SSLConfig,
                local_data_for_mean: Optional[jnp.ndarray] = None) -> VFLClient:
    k_e, k_h = jax.random.split(key)
    e_params = extractor.init(k_e, sample_input)
    head = make_classifier(num_classes)
    reps = extractor.apply(e_params, sample_input[:1])
    h_params = head.init(k_h, reps)
    fm = None
    if local_data_for_mean is not None and local_data_for_mean.ndim == 2:
        fm = jnp.mean(local_data_for_mean, axis=0)
    return VFLClient(index=index, extractor=extractor, head=head,
                     params=ClientParams(e_params, h_params),
                     ssl_cfg=ssl_cfg, feature_mean=fm)


# ----------------------------------------------------------------- SSL loop
def _make_ssl_step(client: VFLClient, tx: optim.GradientTransformation):
    cfg = client.ssl_cfg
    fm = client.feature_mean

    def logits_fn(params: ClientParams, x):
        return client.head.apply(params.head, client.extractor.apply(params.extractor, x))

    @jax.jit
    def step(params, opt_state, key, xb_l, yb_l, xb_u):
        def loss_fn(p):
            return ssl_loss(logits_fn, p, key, xb_l, yb_l, xb_u, cfg, fm)

        (loss, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, metrics

    return step


def local_ssl_train(
    key: jax.Array,
    client: VFLClient,
    x_labeled: jnp.ndarray,
    y_pseudo: jnp.ndarray,
    x_unlabeled: jnp.ndarray,
    epochs: int,
    batch_size: int = 32,
    learning_rate: float = 0.01,
    momentum: float = 0.9,
    unlabeled_ratio: int = 2,
) -> Tuple[VFLClient, dict]:
    """Alg. 1 lines 29-34: epochs of minibatch SSL. Labeled and unlabeled
    minibatches are drawn independently (FixMatch uses μ=unlabeled_ratio×
    larger unlabeled batches)."""
    tx = optim.chain(optim.clip_by_global_norm(5.0),
                     optim.sgd(learning_rate, momentum=momentum))
    opt_state = tx.init(client.params)
    step = _make_ssl_step(client, tx)
    params = client.params

    n_l, n_u = x_labeled.shape[0], x_unlabeled.shape[0]
    bs_l = min(batch_size, n_l)
    bs_u = min(batch_size * unlabeled_ratio, n_u)
    last_metrics: dict = {}
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    for e in range(epochs):
        u_rng = np.random.RandomState(seed0 + 7919 * e)
        for bi, idx_l in enumerate(epoch_batches(n_l, bs_l, seed0 + e)):
            idx_u = u_rng.randint(0, n_u, size=bs_u)
            key, k = jax.random.split(key)
            params, opt_state, m = step(params, opt_state, k,
                                        x_labeled[idx_l], y_pseudo[idx_l],
                                        x_unlabeled[idx_u])
            last_metrics = {k_: float(v) for k_, v in m.items()}
    return replace(client, params=ClientParams(*params)), last_metrics
