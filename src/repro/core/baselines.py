"""Baseline VFL methods from the paper's evaluation (§5.1):

* ``run_vanilla``  — genuine per-round SplitNN iterative VFL: every iteration
  uploads minibatch representations and downloads partial gradients (2 comm
  events per client per iteration, CommLedger-instrumented per round). Also
  used as the end-to-end finetuning stage of "few-shot + finetune" (Tab. 1
  last row).
* ``run_fedbcd``   — FedBCD [20]: Q local updates per communication round
  using the *stale* partial gradients.
* ``run_fedcvt``   — FedCVT-style semi-supervised cross-view baseline [15]:
  iterative VFL where each party's unaligned batch joins training with
  attention-estimated missing-party representations and confidence-gated
  pseudo-labels (the cross-view-training idea, without the paper's full
  5-loss apparatus — see DESIGN.md §7).

``run_vanilla`` and ``run_fedcvt`` execute through the engine's iterative
session path (``repro.engine.iterative``): the whole S-iteration session is
one jitted ``lax.scan`` program (or a Python loop over the cached jitted
step with ``engine_mode="python"``), and the compiled session is cached
across calls so scenario sweeps never recompile identical step math.

All baselines train *only* on information the respective method is allowed
to see; all transfers go through the CommLedger.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import optim
from repro.core.client import ClientParams, VFLClient
from repro.core.comm import CommLedger
from repro.core.protocol import VFLResult, _build_clients, _evaluate
from repro.core.server import VFLServer, concat_reps
from repro.core.ssl import SSLConfig, cross_entropy
from repro.data.loader import epoch_batches
from repro.engine import iterative
from repro.models.extractors import Model, make_classifier


@dataclass(frozen=True)
class IterativeConfig:
    """Frozen (use ``dataclasses.replace`` to derive variants — runner
    signatures default to None and construct a fresh instance, so no call
    ever observes another caller's mutations)."""
    iterations: int = 2000
    batch_size: int = 32
    client_lr: float = 0.01
    server_lr: float = 0.01
    momentum: float = 0.9
    fedbcd_q: int = 5               # Q (paper: 5)
    fedcvt_threshold: float = 0.95
    eval_every: int = 200
    engine_mode: str = "auto"       # "auto" | "scan" | "python" (DESIGN.md §8)

    def iter_hparams(self) -> iterative.IterHParams:
        return iterative.IterHParams(client_lr=self.client_lr,
                                     server_lr=self.server_lr,
                                     momentum=self.momentum,
                                     fedcvt_threshold=self.fedcvt_threshold)


def _init_server(key, server: VFLServer, reps):
    h = concat_reps(reps)
    server.classifier = make_classifier(server.num_classes)
    server.params = server.classifier.init(key, h)
    return server


def _session_carry(clients: Sequence[VFLClient], server: VFLServer,
                   cfg: IterativeConfig):
    """(client_params, server_params, opt_states, opt_state_s) — the engine
    session carry, initialized from the current client/server state."""
    tx_c = optim.sgd(cfg.client_lr, momentum=cfg.momentum)
    tx_s = optim.sgd(cfg.server_lr, momentum=cfg.momentum)
    cp = tuple(ClientParams(*c.params) for c in clients)
    return (cp, server.params,
            tuple(tx_c.init(p) for p in cp), tx_s.init(server.params))


def _log_iterative_rounds(ledger: CommLedger, clients: Sequence[VFLClient],
                          iterations: int, bs: int, payload_factor: int = 1
                          ) -> None:
    """Per-iteration accounting: reps up + rep-grads down per client, both
    (bs, rep_dim) float32 (× payload_factor when a method ships extra
    batches, e.g. FedCVT's unaligned reps). Logged host-side around the
    jitted session so every engine mode produces the identical ledger."""
    for _ in range(iterations):
        r_up, r_dn = ledger.next_round(), ledger.next_round()
        for c in clients:
            num = payload_factor * bs * c.extractor.rep_dim * 4
            ledger.log_bytes(c.index, "up", "reps_batch", num, round=r_up)
            ledger.log_bytes(c.index, "down", "grads_batch", num, round=r_dn)


def run_vanilla(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[IterativeConfig] = None,
    clients: Optional[List[VFLClient]] = None,
    server: Optional[VFLServer] = None,
    ledger: Optional[CommLedger] = None,
) -> VFLResult:
    cfg = cfg if cfg is not None else IterativeConfig()
    ledger = ledger if ledger is not None else CommLedger()
    key, kc, ks = jax.random.split(key, 3)
    if clients is None:
        clients = _build_clients(kc, split, extractors, ssl_cfgs)
    if server is None or server.params is None:
        server = VFLServer(num_classes=split.num_classes)
        reps0 = [c.extract(x[:2]) for c, x in zip(clients, split.aligned)]
        server = _init_server(ks, server, reps0)

    n = split.labels.shape[0]
    bs = min(cfg.batch_size, n)
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    schedule = iterative.build_iteration_schedule(seed0, n, cfg.batch_size,
                                                  cfg.iterations)
    carry = _session_carry(clients, server, cfg)
    carry, losses = iterative.splitnn_session(
        [c.extractor for c in clients], server.classifier, cfg.iter_hparams(),
        carry, split.aligned, split.labels, schedule, mode=cfg.engine_mode)
    cp, sp = carry[0], carry[1]

    _log_iterative_rounds(ledger, clients, cfg.iterations, bs)
    clients = [replace(c, params=ClientParams(*p)) for c, p in zip(clients, cp)]
    server.params = sp
    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server,
                     {"iterations": cfg.iterations,
                      "engine_path": iterative.resolve_mode(cfg.engine_mode),
                      "final_loss": float(losses[-1]) if len(losses) else None})


def run_fedbcd(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[IterativeConfig] = None,
) -> VFLResult:
    """FedBCD-p: per round, one rep exchange then Q parallel local updates on
    the stale partial gradients (clients) / stale reps (server)."""
    cfg = cfg if cfg is not None else IterativeConfig()
    ledger = CommLedger()
    key, kc, ks = jax.random.split(key, 3)
    clients = _build_clients(kc, split, extractors, ssl_cfgs)
    server = VFLServer(num_classes=split.num_classes)
    reps0 = [c.extract(x[:2]) for c, x in zip(clients, split.aligned)]
    server = _init_server(ks, server, reps0)

    txs = [optim.sgd(cfg.client_lr, momentum=cfg.momentum) for _ in clients]
    tx_s = optim.sgd(cfg.server_lr, momentum=cfg.momentum)
    exts = [c.extractor for c in clients]
    clf = server.classifier
    Q = cfg.fedbcd_q

    @jax.jit
    def round_step(client_params, server_params, opt_states, opt_state_s, xs, y):
        # --- one communication round: fresh reps and partial gradients -----
        reps = [ext.apply(p.extractor, x) for ext, p, x in zip(exts, client_params, xs)]

        def rep_loss(rep_list, sp):
            logits = clf.apply(sp, concat_reps(rep_list))
            return jnp.mean(cross_entropy(logits, y))

        g_reps = jax.grad(rep_loss, argnums=0)(reps, server_params)

        # --- Q stale-gradient local updates on each client ------------------
        new_cp, new_os = [], []
        for ext, p, os_, tx, x, g in zip(exts, client_params, opt_states, txs, xs, g_reps):
            def q_body(_, carry):
                p_, os__ = carry
                def local_obj(pp):
                    # <stale ∂L/∂H, f_k(x; θ)> — the FedBCD surrogate
                    return jnp.sum(jax.lax.stop_gradient(g) * ext.apply(pp.extractor, x))
                gq = jax.grad(local_obj)(p_)
                upd, os__ = tx.update(gq, os__, p_)
                return optim.apply_updates(p_, upd), os__
            p, os_ = jax.lax.fori_loop(0, Q, q_body, (p, os_))
            new_cp.append(p)
            new_os.append(os_)

        # --- Q server updates on the stale reps -----------------------------
        def s_body(_, carry):
            sp, os_s = carry
            gs = jax.grad(lambda spp: rep_loss([jax.lax.stop_gradient(r) for r in reps], spp))(sp)
            upd, os_s = tx_s.update(gs, os_s, sp)
            return optim.apply_updates(sp, upd), os_s
        server_params, opt_state_s = jax.lax.fori_loop(0, Q, s_body, (server_params, opt_state_s))
        return new_cp, server_params, new_os, opt_state_s

    client_params = [c.params for c in clients]
    server_params = server.params
    opt_states = [tx.init(p) for tx, p in zip(txs, client_params)]
    opt_state_s = tx_s.init(server_params)

    n = split.labels.shape[0]
    bs = min(cfg.batch_size, n)
    rep_dim = clients[0].extractor.rep_dim
    rounds = cfg.iterations // Q
    it = 0
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    while it < rounds:
        for idx in epoch_batches(n, bs, seed0 + it):
            if it >= rounds:
                break
            xs = [x[idx] for x in split.aligned]
            client_params, server_params, opt_states, opt_state_s = round_step(
                client_params, server_params, opt_states, opt_state_s,
                xs, split.labels[idx])
            r_up, r_dn = ledger.next_round(), ledger.next_round()
            for c in clients:
                ledger.log_bytes(c.index, "up", "reps_batch", bs * rep_dim * 4, round=r_up)
                ledger.log_bytes(c.index, "down", "grads_batch", bs * rep_dim * 4, round=r_dn)
            it += 1

    clients = [replace(c, params=ClientParams(*p)) for c, p in zip(clients, client_params)]
    server.params = server_params
    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server,
                     {"rounds": rounds, "Q": Q})


def run_fedcvt(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[IterativeConfig] = None,
) -> VFLResult:
    """FedCVT-style semi-supervised baseline: vanilla iterative VFL +
    per-iteration cross-view training-set expansion. Each round, missing
    reps of a sampled unaligned batch are attention-estimated from the
    overlap batch and samples whose classifier confidence exceeds the
    threshold train with their pseudo labels. Runs as one engine session
    (``repro.engine.iterative.fedcvt_session``)."""
    cfg = cfg if cfg is not None else IterativeConfig()
    ledger = CommLedger()
    key, kc, ks = jax.random.split(key, 3)
    clients = _build_clients(kc, split, extractors, ssl_cfgs)
    server = VFLServer(num_classes=split.num_classes)
    reps0 = [c.extract(x[:2]) for c, x in zip(clients, split.aligned)]
    server = _init_server(ks, server, reps0)

    n = split.labels.shape[0]
    bs = min(cfg.batch_size, n)
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    schedule = iterative.build_iteration_schedule(seed0, n, cfg.batch_size,
                                                  cfg.iterations)
    u_schedules = iterative.build_unaligned_schedule(
        0, [x.shape[0] for x in split.unaligned], bs, cfg.iterations)
    carry = _session_carry(clients, server, cfg)
    carry, losses = iterative.fedcvt_session(
        [c.extractor for c in clients], server.classifier, cfg.iter_hparams(),
        carry, split.aligned, split.labels, schedule,
        split.unaligned, u_schedules, mode=cfg.engine_mode)
    cp, sp = carry[0], carry[1]

    # overlap reps + unaligned reps up; both gradients down
    _log_iterative_rounds(ledger, clients, cfg.iterations, bs,
                          payload_factor=2)
    clients = [replace(c, params=ClientParams(*p)) for c, p in zip(clients, cp)]
    server.params = sp
    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server,
                     {"iterations": cfg.iterations,
                      "engine_path": iterative.resolve_mode(cfg.engine_mode),
                      "final_loss": float(losses[-1]) if len(losses) else None})
