"""Baseline VFL methods from the paper's evaluation (§5.1):

* ``run_vanilla``  — genuine per-round SplitNN iterative VFL: every iteration
  uploads minibatch representations and downloads partial gradients (2 comm
  events per client per iteration, CommLedger-instrumented per round). Also
  used as the end-to-end finetuning stage of "few-shot + finetune" (Tab. 1
  last row).
* ``run_fedbcd``   — FedBCD [20]: Q local updates per communication round
  using the *stale* partial gradients.
* ``run_fedcvt``   — FedCVT-style semi-supervised cross-view baseline [15]:
  iterative VFL where each party's unaligned batch joins training with
  attention-estimated missing-party representations and confidence-gated
  pseudo-labels (the cross-view-training idea, without the paper's full
  5-loss apparatus — see DESIGN.md §7).

Every baseline executes through the engine's iterative session path
(``repro.engine.iterative``): the whole S-iteration session is one jitted
``lax.scan`` program (or a Python loop over the cached jitted step with
``engine_mode="python"``), and the compiled session is cached across calls
so scenario sweeps never recompile identical step math.

Each runner is the S = 1 case of a seed-batched ``*_seeds`` entry
(DESIGN.md §11, mirroring the protocol's ``_one_shot_seeds`` pattern):
``run_vanilla_seeds`` / ``run_fedcvt_seeds`` / ``run_fedbcd_seeds`` stack
S seeds' whole-session carries on a leading seed axis and train them as
ONE ``vmap``-of-scan program (``engine.batched``), with each seed's exact
single-seed key/schedule discipline reproduced host-side and the
communication ledger — a function of shapes, which are seed-invariant —
produced once and shared by every per-seed result.
``core.protocol.run_seeds`` routes the baselines here.

All baselines train *only* on information the respective method is allowed
to see; all transfers go through the CommLedger.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core.client import ClientParams, VFLClient
from repro.core.comm import CommLedger
from repro.core.protocol import VFLResult, _build_clients, _evaluate
from repro.core.server import VFLServer, concat_reps
from repro.core.ssl import SSLConfig
from repro.data.loader import epoch_batches
from repro.engine import batched, iterative, parallel
from repro.models.extractors import Model, make_classifier


@dataclass(frozen=True)
class IterativeConfig:
    """Frozen (use ``dataclasses.replace`` to derive variants — runner
    signatures default to None and construct a fresh instance, so no call
    ever observes another caller's mutations)."""
    iterations: int = 2000
    batch_size: int = 32
    client_lr: float = 0.01
    server_lr: float = 0.01
    momentum: float = 0.9
    fedbcd_q: int = 5               # Q (paper: 5)
    fedcvt_threshold: float = 0.95
    eval_every: int = 200
    engine_mode: str = "auto"       # "auto" | "scan" | "python" (DESIGN.md §8)
    mesh: object = None             # device mesh for the stacked seed axis
                                    # (DESIGN.md §14): None | device count |
                                    # jax.sharding.Mesh; None consults the
                                    # REPRO_DEVICE_COUNT env knob

    def iter_hparams(self) -> iterative.IterHParams:
        return iterative.IterHParams(client_lr=self.client_lr,
                                     server_lr=self.server_lr,
                                     momentum=self.momentum,
                                     fedcvt_threshold=self.fedcvt_threshold)


def _init_server(key, server: VFLServer, reps):
    h = concat_reps(reps)
    server.classifier = make_classifier(server.num_classes)
    server.params = server.classifier.init(key, h)
    return server


def _session_carry(clients: Sequence[VFLClient], server: VFLServer,
                   cfg: IterativeConfig):
    """(client_params, server_params, opt_states, opt_state_s) — the engine
    session carry, initialized from the current client/server state."""
    tx_c = optim.sgd(cfg.client_lr, momentum=cfg.momentum)
    tx_s = optim.sgd(cfg.server_lr, momentum=cfg.momentum)
    cp = tuple(ClientParams(*c.params) for c in clients)
    return (cp, server.params,
            tuple(tx_c.init(p) for p in cp), tx_s.init(server.params))


def _log_iterative_rounds(ledger: CommLedger, clients: Sequence[VFLClient],
                          iterations: int, bs: int, payload_factor: int = 1
                          ) -> None:
    """Per-iteration accounting: reps up + rep-grads down per client, both
    (bs, rep_dim) float32 (× payload_factor when a method ships extra
    batches, e.g. FedCVT's unaligned reps). Logged host-side around the
    jitted session so every engine mode produces the identical ledger."""
    for _ in range(iterations):
        r_up, r_dn = ledger.next_round(), ledger.next_round()
        for c in clients:
            num = payload_factor * bs * c.extractor.rep_dim * 4
            ledger.log_bytes(c.index, "up", "reps_batch", num, round=r_up)
            ledger.log_bytes(c.index, "down", "grads_batch", num, round=r_dn)


def _iterative_fault_plan(faults, clients, n_steps: int, bs: int,
                          payload_factor: int = 1):
    """Per-entry ledgers + dropout modeling for an iterative baseline fold
    (DESIGN.md §16). The synchronous round loop has no estimator to
    recover a dropped party, so the session stalls at the drop step:
    normal per-iteration accounting runs to ``t_drop``, then the server
    burns ``retry_rounds`` extra communication rounds — every surviving
    client re-uploads its batch while the dropped party gets a 4-byte
    timeout probe — before the method gives up with the carry it has.
    Straggler / dp_upload / representation_only faults have no analogue
    in the round loop: those entries run fault-free and are marked
    ``fault_modeled: False``.

    Returns ``(ledgers, active_steps | None, per_entry_diags)``;
    ``active_steps`` is the (S,) per-entry commit horizon the engine's
    faulted scan variant consumes (``iterative.run_iterative_session_seeds``).
    """
    num = len(faults)
    num_parties = len(clients)
    ledgers = [CommLedger() for _ in range(num)]
    diags: List[dict] = [{} for _ in range(num)]
    active = [n_steps] * num
    any_drop = False
    for s, fa in enumerate(faults):
        if fa is None or fa.kind != "dropout":
            _log_iterative_rounds(ledgers[s], clients, n_steps, bs,
                                  payload_factor)
            diags[s]["fault_kind"] = "none" if fa is None else fa.kind
            diags[s]["parties_survived"] = num_parties
            if fa is not None:
                diags[s]["fault_modeled"] = False
            continue
        any_drop = True
        t_drop = fa.iterative_active_steps(n_steps)
        active[s] = t_drop
        _log_iterative_rounds(ledgers[s], clients, t_drop, bs,
                              payload_factor)
        retry_bytes = 0
        for _ in range(fa.retry_rounds):
            r_up, r_dn = ledgers[s].next_round(), ledgers[s].next_round()
            for c in clients:
                if c.index == fa.party:
                    continue
                nb = payload_factor * bs * c.extractor.rep_dim * 4
                ledgers[s].log_bytes(c.index, "up", "retry_reps", nb,
                                     round=r_up)
                retry_bytes += nb
            ledgers[s].log_bytes(fa.party, "down", "retry_timeout", 4,
                                 round=r_dn)
            retry_bytes += 4
        diags[s].update({"fault_kind": fa.kind, "fault_stage": fa.stage,
                         "parties_survived":
                             fa.parties_survived(num_parties),
                         "fault_modeled": True,
                         "fault_retry_rounds": fa.retry_rounds,
                         "fault_retry_bytes": retry_bytes})
    return ledgers, (jnp.asarray(active, jnp.int32) if any_drop
                     else None), diags


def _seed_sessions_setup(keys, splits, extractors, ssl_cfgs,
                         cfg: IterativeConfig, make_schedule,
                         clients_per_seed=None, servers=None):
    """The per-seed setup every ``*_seeds`` runner shares — ONE
    implementation of the single-seed key discipline (``key, kc, ks =
    split(keys[s], 3)``, clients from ``kc``, server init from ``ks``,
    ``seed0`` drawn from ``key``) that the parity tests pin.
    ``make_schedule(seed0, n)`` builds the runner's minibatch schedule.
    Returns ``(clients_all, servers_all, schedules, carries)``."""
    num_seeds = len(keys)
    clients_all, servers_all, schedules, carries = [], [], [], []
    for s in range(num_seeds):
        key, kc, ks = jax.random.split(keys[s], 3)
        given = clients_per_seed[s] if clients_per_seed is not None else None
        clients = (given if given is not None else
                   _build_clients(kc, splits[s], extractors[s], ssl_cfgs[s]))
        server = servers[s] if servers is not None else None
        if server is None or server.params is None:
            server = VFLServer(num_classes=splits[s].num_classes)
            reps0 = [c.extract(x[:2])
                     for c, x in zip(clients, splits[s].aligned)]
            server = _init_server(ks, server, reps0)
        seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
        schedules.append(make_schedule(seed0, splits[s].labels.shape[0]))
        carries.append(_session_carry(clients, server, cfg))
        clients_all.append(clients)
        servers_all.append(server)
    return clients_all, servers_all, schedules, carries


def _finish_seed_results(cfg: IterativeConfig, ledger: CommLedger,
                         clients_all, servers, splits, carries, losses,
                         extra_diags=None, ledgers=None,
                         per_seed_diags=None, faults=None
                         ) -> List[VFLResult]:
    """Shared tail of every seed-batched baseline: install the trained
    carries, evaluate per seed, and attach the (shared) ledger — callers
    copy it per seed when S > 1 (``run_seeds`` does). Faulted folds pass
    per-entry ``ledgers`` / ``per_seed_diags`` / ``faults`` instead: a
    dropped party's test reps are zero-imputed at eval (no estimator in
    the iterative methods) and the degraded metric is recorded."""
    num_seeds = len(carries)
    results = []
    for s in range(num_seeds):
        cp, sp = carries[s][0], carries[s][1]
        clients = [replace(c, params=ClientParams(*p))
                   for c, p in zip(clients_all[s], cp)]
        servers[s].params = sp
        fa = faults[s] if faults is not None else None
        name, metric = _evaluate(
            servers[s], clients, splits[s],
            fault=fa if fa is not None and fa.kind == "dropout" else None)
        path = iterative.resolve_mode(cfg.engine_mode)
        diag = {"engine_path": path,
                "seed_fold": num_seeds,
                "device_fold": (parallel.device_fold(
                    parallel.resolve_mesh(cfg.mesh))
                    if path == "scan" else 1),
                "final_loss": (float(losses[s][-1]) if losses.shape[1]
                               else None)}
        if extra_diags is not None:
            diag.update(extra_diags)
        if per_seed_diags is not None:
            diag.update(per_seed_diags[s])
            diag["degraded_metric"] = float(metric)
        results.append(VFLResult(name, metric,
                                 ledgers[s] if ledgers is not None
                                 else ledger,
                                 clients, servers[s], diag))
    return results


def run_vanilla_seeds(
    keys: Sequence[jax.Array],
    splits: Sequence,
    extractors: Sequence[Sequence[Model]],
    ssl_cfgs: Sequence[Sequence[SSLConfig]],
    cfg: Optional[IterativeConfig] = None,
    clients_per_seed: Optional[Sequence[Optional[List[VFLClient]]]] = None,
    servers: Optional[Sequence[Optional[VFLServer]]] = None,
    ledger: Optional[CommLedger] = None,
    faults: Optional[Sequence] = None,
) -> List[VFLResult]:
    """Vanilla SplitNN VFL over S seeds at once (DESIGN.md §11): every
    seed's whole-session ``lax.scan`` carry stacks on a leading seed axis
    and the fold trains as one program. Per-seed PRNG/schedule discipline
    matches the historical single-seed runner exactly — S = 1 *is*
    ``run_vanilla``. All results share ``ledger`` (bytes are a function of
    shapes, seed-invariant); multi-seed callers copy it per result.

    ``clients_per_seed`` / ``servers`` admit pre-trained per-seed state —
    the chained few-shot + finetune fold threads the folded few-shot
    output carry straight into this folded finetune session.

    ``faults`` (one Optional[FaultSpec] per entry, §16) switches to
    per-entry ledgers: a dropout truncates the entry's committed round
    loop (``active_steps`` — fault mask as data, same compiled session)
    and charges the retry/timeout rounds; other fault kinds are not
    modeled by the synchronous loop (``fault_modeled: False``)."""
    cfg = cfg if cfg is not None else IterativeConfig()
    faulted = faults is not None and any(fa is not None for fa in faults)
    ledger = ledger if ledger is not None else CommLedger()
    clients_all, servers_all, schedules, carries = _seed_sessions_setup(
        keys, splits, extractors, ssl_cfgs, cfg,
        lambda seed0, n: iterative.build_iteration_schedule(
            seed0, n, cfg.batch_size, cfg.iterations),
        clients_per_seed=clients_per_seed, servers=servers)
    bs = min(cfg.batch_size, splits[0].labels.shape[0])
    fault_ledgers = active = fault_diags = None
    if faulted:
        fault_ledgers, active, fault_diags = _iterative_fault_plan(
            faults, clients_all[0], cfg.iterations, bs)
    carries, losses = batched.splitnn_sessions_seeds(
        [[c.extractor for c in cl] for cl in clients_all],
        [srv.classifier for srv in servers_all], cfg.iter_hparams(),
        carries, [sp.aligned for sp in splits],
        [sp.labels for sp in splits], schedules, mode=cfg.engine_mode,
        mesh=cfg.mesh, active_steps=active)

    if not faulted:
        _log_iterative_rounds(ledger, clients_all[0], cfg.iterations, bs)
    return _finish_seed_results(cfg, ledger, clients_all, servers_all,
                                splits, carries, losses,
                                {"iterations": cfg.iterations},
                                ledgers=fault_ledgers,
                                per_seed_diags=fault_diags, faults=faults)


def run_vanilla(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[IterativeConfig] = None,
    clients: Optional[List[VFLClient]] = None,
    server: Optional[VFLServer] = None,
    ledger: Optional[CommLedger] = None,
    fault=None,
) -> VFLResult:
    return run_vanilla_seeds([key], [split], [extractors], [ssl_cfgs], cfg,
                             clients_per_seed=[clients], servers=[server],
                             ledger=ledger,
                             faults=None if fault is None else [fault])[0]


def _fedbcd_schedule(seed0: int, n: int, batch_size: int,
                     rounds: int) -> jnp.ndarray:
    """(rounds, bs) minibatch indices replicating the historical FedBCD
    loop exactly: each shuffled epoch is seeded ``seed0 + rounds_done`` at
    its *entry* (not ``seed0 + epoch`` — the historical loop reseeded on
    the round counter), drop-remainder, truncated to ``rounds`` rows."""
    bs = min(batch_size, n)
    if rounds <= 0:
        return jnp.zeros((0, bs), jnp.int32)
    rows: List[np.ndarray] = []
    while len(rows) < rounds:
        for b in epoch_batches(n, bs, seed0 + len(rows)):
            rows.append(b)
            if len(rows) == rounds:
                break
    return jnp.asarray(np.stack(rows), jnp.int32)


def run_fedbcd_seeds(
    keys: Sequence[jax.Array],
    splits: Sequence,
    extractors: Sequence[Sequence[Model]],
    ssl_cfgs: Sequence[Sequence[SSLConfig]],
    cfg: Optional[IterativeConfig] = None,
    faults: Optional[Sequence] = None,
) -> List[VFLResult]:
    """FedBCD-p over S seeds at once: per round, one rep exchange then Q
    parallel local updates on the stale partial gradients (clients) / stale
    reps (server) — the whole multi-seed session one folded scan program
    (DESIGN.md §11), where it used to re-``jax.jit`` an ad-hoc round step
    per call. ``faults``: see :func:`run_vanilla_seeds` — the dropout
    horizon counts communication ROUNDS (the scan axis), not local
    updates."""
    cfg = cfg if cfg is not None else IterativeConfig()
    faulted = faults is not None and any(fa is not None for fa in faults)
    ledger = CommLedger()
    rounds = cfg.iterations // cfg.fedbcd_q
    clients_all, servers_all, schedules, carries = _seed_sessions_setup(
        keys, splits, extractors, ssl_cfgs, cfg,
        lambda seed0, n: _fedbcd_schedule(seed0, n, cfg.batch_size, rounds))
    bs = min(cfg.batch_size, splits[0].labels.shape[0])
    fault_ledgers = active = fault_diags = None
    if faulted:
        fault_ledgers, active, fault_diags = _iterative_fault_plan(
            faults, clients_all[0], rounds, bs)
    carries, losses = batched.fedbcd_sessions_seeds(
        [[c.extractor for c in cl] for cl in clients_all],
        [srv.classifier for srv in servers_all], cfg.iter_hparams(),
        cfg.fedbcd_q, carries, [sp.aligned for sp in splits],
        [sp.labels for sp in splits], schedules, mode=cfg.engine_mode,
        mesh=cfg.mesh, active_steps=active)

    if not faulted:
        _log_iterative_rounds(ledger, clients_all[0], rounds, bs)
    return _finish_seed_results(cfg, ledger, clients_all, servers_all,
                                splits, carries, losses,
                                {"rounds": rounds, "Q": cfg.fedbcd_q},
                                ledgers=fault_ledgers,
                                per_seed_diags=fault_diags, faults=faults)


def run_fedbcd(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[IterativeConfig] = None,
    fault=None,
) -> VFLResult:
    return run_fedbcd_seeds([key], [split], [extractors], [ssl_cfgs], cfg,
                            faults=None if fault is None else [fault])[0]


def run_fedcvt_seeds(
    keys: Sequence[jax.Array],
    splits: Sequence,
    extractors: Sequence[Sequence[Model]],
    ssl_cfgs: Sequence[Sequence[SSLConfig]],
    cfg: Optional[IterativeConfig] = None,
    faults: Optional[Sequence] = None,
) -> List[VFLResult]:
    """FedCVT-style semi-supervised baseline over S seeds at once: vanilla
    iterative VFL + per-iteration cross-view training-set expansion. Each
    round, missing reps of a sampled unaligned batch are attention-
    estimated from the overlap batch and samples whose classifier
    confidence exceeds the threshold train with their pseudo labels. The
    whole multi-seed session is one folded scan program
    (``engine.batched.fedcvt_sessions_seeds``, DESIGN.md §11).
    ``faults``: see :func:`run_vanilla_seeds` (retry payloads carry the
    same 2× factor as the normal rounds)."""
    cfg = cfg if cfg is not None else IterativeConfig()
    faulted = faults is not None and any(fa is not None for fa in faults)
    ledger = CommLedger()
    clients_all, servers_all, schedules, carries = _seed_sessions_setup(
        keys, splits, extractors, ssl_cfgs, cfg,
        lambda seed0, n: iterative.build_iteration_schedule(
            seed0, n, cfg.batch_size, cfg.iterations))
    # the unaligned draws are key-free (historically seeded literally 0):
    # only pool sizes and the batch width enter
    u_schedules = [iterative.build_unaligned_schedule(
        0, [x.shape[0] for x in sp.unaligned],
        min(cfg.batch_size, sp.labels.shape[0]), cfg.iterations)
        for sp in splits]
    bs = min(cfg.batch_size, splits[0].labels.shape[0])
    fault_ledgers = active = fault_diags = None
    if faulted:
        fault_ledgers, active, fault_diags = _iterative_fault_plan(
            faults, clients_all[0], cfg.iterations, bs, payload_factor=2)
    carries, losses = batched.fedcvt_sessions_seeds(
        [[c.extractor for c in cl] for cl in clients_all],
        [srv.classifier for srv in servers_all], cfg.iter_hparams(),
        carries, [sp.aligned for sp in splits],
        [sp.labels for sp in splits], schedules,
        [sp.unaligned for sp in splits], u_schedules,
        mode=cfg.engine_mode, mesh=cfg.mesh, active_steps=active)

    # overlap reps + unaligned reps up; both gradients down
    if not faulted:
        _log_iterative_rounds(ledger, clients_all[0], cfg.iterations, bs,
                              payload_factor=2)
    return _finish_seed_results(cfg, ledger, clients_all, servers_all,
                                splits, carries, losses,
                                {"iterations": cfg.iterations},
                                ledgers=fault_ledgers,
                                per_seed_diags=fault_diags, faults=faults)


def run_fedcvt(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[IterativeConfig] = None,
    fault=None,
) -> VFLResult:
    return run_fedcvt_seeds([key], [split], [extractors], [ssl_cfgs], cfg,
                            faults=None if fault is None else [fault])[0]
