"""Baseline VFL methods from the paper's evaluation (§5.1):

* ``run_vanilla``  — SplitNN-style iterative VFL: every iteration uploads
  minibatch representations and downloads partial gradients (2 comm events
  per client per iteration). Also used as the end-to-end finetuning stage of
  "few-shot + finetune" (Tab. 1 last row).
* ``run_fedbcd``   — FedBCD [20]: Q local updates per communication round
  using the *stale* partial gradients.
* ``run_fedcvt``   — FedCVT-lite [15]: iterative VFL where the server expands
  each batch with unaligned samples whose missing-party representations are
  attention-estimated from the overlap set and whose pseudo-labels pass a
  confidence threshold (the cross-view-training idea, without the paper's
  full 5-loss apparatus — see DESIGN.md §7).

All baselines train *only* on information the respective method is allowed to
see; all transfers go through the CommLedger.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.core import estimator
from repro.core.client import ClientParams, VFLClient, make_client
from repro.core.comm import CommLedger
from repro.core.metrics import accuracy, binary_auc
from repro.core.protocol import ProtocolConfig, VFLResult, _build_clients, _evaluate
from repro.core.server import VFLServer, concat_reps
from repro.core.ssl import SSLConfig, cross_entropy
from repro.data.loader import epoch_batches
from repro.models.extractors import Model, make_classifier


@dataclass
class IterativeConfig:
    iterations: int = 2000
    batch_size: int = 32
    client_lr: float = 0.01
    server_lr: float = 0.01
    fedbcd_q: int = 5               # Q (paper: 5)
    fedcvt_threshold: float = 0.95
    eval_every: int = 200


def _init_server(key, server: VFLServer, reps):
    h = concat_reps(reps)
    server.classifier = make_classifier(server.num_classes)
    server.params = server.classifier.init(key, h)
    return server


def _make_vanilla_step(clients: Sequence[VFLClient], server: VFLServer,
                       cfg: IterativeConfig):
    """Jointly-differentiated SplitNN iteration. Gradients are computed in one
    jax.grad for efficiency, but the *communication* is exactly: reps up,
    rep-grads down (logged by the caller with the true tensor sizes)."""
    txs = [optim.sgd(cfg.client_lr, momentum=0.9) for _ in clients]
    tx_s = optim.sgd(cfg.server_lr, momentum=0.9)
    extractors = [c.extractor for c in clients]
    classifier_apply = None  # bound at first call via server.classifier

    def make(server_classifier):
        @jax.jit
        def step(client_params: List, server_params, opt_states, opt_state_s,
                 xs, y):
            def loss_fn(cp_list, sp):
                reps = [ext.apply(p.extractor, x)
                        for ext, p, x in zip(extractors, cp_list, xs)]
                logits = server_classifier.apply(sp, concat_reps(reps))
                return jnp.mean(cross_entropy(logits, y))

            loss, (g_clients, g_server) = jax.value_and_grad(
                loss_fn, argnums=(0, 1))(client_params, server_params)
            new_cp, new_os = [], []
            for p, g, tx, os_ in zip(client_params, g_clients, txs, opt_states):
                upd, os_ = tx.update(g, os_, p)
                new_cp.append(optim.apply_updates(p, upd))
                new_os.append(os_)
            upd_s, opt_state_s = tx_s.update(g_server, opt_state_s, server_params)
            server_params = optim.apply_updates(server_params, upd_s)
            return new_cp, server_params, new_os, opt_state_s, loss

        return step

    return make, txs, tx_s


def run_vanilla(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: IterativeConfig = IterativeConfig(),
    clients: Optional[List[VFLClient]] = None,
    server: Optional[VFLServer] = None,
    ledger: Optional[CommLedger] = None,
) -> VFLResult:
    ledger = ledger if ledger is not None else CommLedger()
    key, kc, ks = jax.random.split(key, 3)
    if clients is None:
        clients = _build_clients(kc, split, extractors, ssl_cfgs)
    if server is None or server.params is None:
        server = VFLServer(num_classes=split.num_classes)
        reps0 = [c.extract(x[:2]) for c, x in zip(clients, split.aligned)]
        server = _init_server(ks, server, reps0)

    make_step, txs, tx_s = _make_vanilla_step(clients, server, cfg)
    step = make_step(server.classifier)
    client_params = [c.params for c in clients]
    server_params = server.params
    opt_states = [tx.init(p) for tx, p in zip(txs, client_params)]
    opt_state_s = tx_s.init(server_params)

    n = split.labels.shape[0]
    bs = min(cfg.batch_size, n)
    rep_dim = clients[0].extractor.rep_dim
    it = 0
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    while it < cfg.iterations:
        for idx in epoch_batches(n, bs, seed0 + it):
            if it >= cfg.iterations:
                break
            xs = [x[idx] for x in split.aligned]
            client_params, server_params, opt_states, opt_state_s, loss = step(
                client_params, server_params, opt_states, opt_state_s,
                xs, split.labels[idx])
            # communication: reps up + grads down, both (bs, rep_dim) f32
            r_up, r_dn = ledger.next_round(), ledger.next_round()
            for c in clients:
                ledger.log_bytes(c.index, "up", "reps_batch", bs * rep_dim * 4, round=r_up)
                ledger.log_bytes(c.index, "down", "grads_batch", bs * rep_dim * 4, round=r_dn)
            it += 1

    clients = [replace(c, params=ClientParams(*p)) for c, p in zip(clients, client_params)]
    server.params = server_params
    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server,
                     {"iterations": cfg.iterations})


def run_fedbcd(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: IterativeConfig = IterativeConfig(),
) -> VFLResult:
    """FedBCD-p: per round, one rep exchange then Q parallel local updates on
    the stale partial gradients (clients) / stale reps (server)."""
    ledger = CommLedger()
    key, kc, ks = jax.random.split(key, 3)
    clients = _build_clients(kc, split, extractors, ssl_cfgs)
    server = VFLServer(num_classes=split.num_classes)
    reps0 = [c.extract(x[:2]) for c, x in zip(clients, split.aligned)]
    server = _init_server(ks, server, reps0)

    txs = [optim.sgd(cfg.client_lr, momentum=0.9) for _ in clients]
    tx_s = optim.sgd(cfg.server_lr, momentum=0.9)
    exts = [c.extractor for c in clients]
    clf = server.classifier
    Q = cfg.fedbcd_q

    @jax.jit
    def round_step(client_params, server_params, opt_states, opt_state_s, xs, y):
        # --- one communication round: fresh reps and partial gradients -----
        reps = [ext.apply(p.extractor, x) for ext, p, x in zip(exts, client_params, xs)]

        def rep_loss(rep_list, sp):
            logits = clf.apply(sp, concat_reps(rep_list))
            return jnp.mean(cross_entropy(logits, y))

        g_reps = jax.grad(rep_loss, argnums=0)(reps, server_params)

        # --- Q stale-gradient local updates on each client ------------------
        new_cp, new_os = [], []
        for ext, p, os_, tx, x, g in zip(exts, client_params, opt_states, txs, xs, g_reps):
            def q_body(_, carry):
                p_, os__ = carry
                def local_obj(pp):
                    # <stale ∂L/∂H, f_k(x; θ)> — the FedBCD surrogate
                    return jnp.sum(jax.lax.stop_gradient(g) * ext.apply(pp.extractor, x))
                gq = jax.grad(local_obj)(p_)
                upd, os__ = tx.update(gq, os__, p_)
                return optim.apply_updates(p_, upd), os__
            p, os_ = jax.lax.fori_loop(0, Q, q_body, (p, os_))
            new_cp.append(p)
            new_os.append(os_)

        # --- Q server updates on the stale reps -----------------------------
        def s_body(_, carry):
            sp, os_s = carry
            gs = jax.grad(lambda spp: rep_loss([jax.lax.stop_gradient(r) for r in reps], spp))(sp)
            upd, os_s = tx_s.update(gs, os_s, sp)
            return optim.apply_updates(sp, upd), os_s
        server_params, opt_state_s = jax.lax.fori_loop(0, Q, s_body, (server_params, opt_state_s))
        return new_cp, server_params, new_os, opt_state_s

    client_params = [c.params for c in clients]
    server_params = server.params
    opt_states = [tx.init(p) for tx, p in zip(txs, client_params)]
    opt_state_s = tx_s.init(server_params)

    n = split.labels.shape[0]
    bs = min(cfg.batch_size, n)
    rep_dim = clients[0].extractor.rep_dim
    rounds = cfg.iterations // Q
    it = 0
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    while it < rounds:
        for idx in epoch_batches(n, bs, seed0 + it):
            if it >= rounds:
                break
            xs = [x[idx] for x in split.aligned]
            client_params, server_params, opt_states, opt_state_s = round_step(
                client_params, server_params, opt_states, opt_state_s,
                xs, split.labels[idx])
            r_up, r_dn = ledger.next_round(), ledger.next_round()
            for c in clients:
                ledger.log_bytes(c.index, "up", "reps_batch", bs * rep_dim * 4, round=r_up)
                ledger.log_bytes(c.index, "down", "grads_batch", bs * rep_dim * 4, round=r_dn)
            it += 1

    clients = [replace(c, params=ClientParams(*p)) for c, p in zip(clients, client_params)]
    server.params = server_params
    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server,
                     {"rounds": rounds, "Q": Q})


def run_fedcvt(
    key: jax.Array,
    split,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: IterativeConfig = IterativeConfig(),
) -> VFLResult:
    """FedCVT-lite: vanilla iterative VFL + per-iteration training-set
    expansion. Each round, the server attention-estimates missing reps of a
    sampled unaligned batch and keeps samples whose classifier confidence
    exceeds the threshold, training on them with their pseudo labels."""
    ledger = CommLedger()
    key, kc, ks = jax.random.split(key, 3)
    clients = _build_clients(kc, split, extractors, ssl_cfgs)
    server = VFLServer(num_classes=split.num_classes)
    reps0 = [c.extract(x[:2]) for c, x in zip(clients, split.aligned)]
    server = _init_server(ks, server, reps0)

    txs = [optim.sgd(cfg.client_lr, momentum=0.9) for _ in clients]
    tx_s = optim.sgd(cfg.server_lr, momentum=0.9)
    exts = [c.extractor for c in clients]
    clf = server.classifier
    K = len(clients)

    @jax.jit
    def step(client_params, server_params, opt_states, opt_state_s,
             xs_o, y, xs_u):
        def loss_fn(cp_list, sp):
            reps_o = [ext.apply(p.extractor, x) for ext, p, x in zip(exts, cp_list, xs_o)]
            logits = clf.apply(sp, concat_reps(reps_o))
            loss = jnp.mean(cross_entropy(logits, y))
            # cross-view expansion: for each party's unaligned batch, estimate
            # the other parties' reps from the *overlap* batch reps
            for k_idx in range(K):
                h_u = exts[k_idx].apply(cp_list[k_idx].extractor, xs_u[k_idx])
                parts = []
                for j in range(K):
                    if j == k_idx:
                        parts.append(h_u)
                    else:
                        parts.append(estimator.sdpa_transform(h_u, reps_o[k_idx], reps_o[j]))
                logits_u = clf.apply(sp, concat_reps(parts))
                p_u = jax.nn.softmax(jax.lax.stop_gradient(logits_u), axis=-1)
                pseudo = jnp.argmax(p_u, axis=-1)
                mask = (jnp.max(p_u, axis=-1) > cfg.fedcvt_threshold).astype(jnp.float32)
                ce = cross_entropy(logits_u, pseudo)
                loss = loss + jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask), 1.0)
            return loss

        loss, (g_c, g_s) = jax.value_and_grad(loss_fn, argnums=(0, 1))(
            client_params, server_params)
        new_cp, new_os = [], []
        for p, g, tx, os_ in zip(client_params, g_c, txs, opt_states):
            upd, os_ = tx.update(g, os_, p)
            new_cp.append(optim.apply_updates(p, upd))
            new_os.append(os_)
        upd_s, opt_state_s = tx_s.update(g_s, opt_state_s, server_params)
        return new_cp, optim.apply_updates(server_params, upd_s), new_os, opt_state_s, loss

    client_params = [c.params for c in clients]
    server_params = server.params
    opt_states = [tx.init(p) for tx, p in zip(txs, client_params)]
    opt_state_s = tx_s.init(server_params)

    n = split.labels.shape[0]
    bs = min(cfg.batch_size, n)
    rep_dim = clients[0].extractor.rep_dim
    rng = np.random.RandomState(0)
    it = 0
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    while it < cfg.iterations:
        for idx in epoch_batches(n, bs, seed0 + it):
            if it >= cfg.iterations:
                break
            xs_o = [x[idx] for x in split.aligned]
            xs_u = [x[rng.randint(0, x.shape[0], size=bs)] for x in split.unaligned]
            client_params, server_params, opt_states, opt_state_s, _ = step(
                client_params, server_params, opt_states, opt_state_s,
                xs_o, split.labels[idx], xs_u)
            r_up, r_dn = ledger.next_round(), ledger.next_round()
            for c in clients:
                # overlap reps + unaligned reps up; both gradients down
                ledger.log_bytes(c.index, "up", "reps_batch", 2 * bs * rep_dim * 4, round=r_up)
                ledger.log_bytes(c.index, "down", "grads_batch", 2 * bs * rep_dim * 4, round=r_dn)
            it += 1

    clients = [replace(c, params=ClientParams(*p)) for c, p in zip(clients, client_params)]
    server.params = server_params
    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server, {"iterations": cfg.iterations})
