"""One-shot and few-shot VFL protocol orchestration (Alg. 1 + Alg. 2).

``run_one_shot`` / ``run_few_shot`` are THIN orchestrators: they do the
ledger-tracked client↔server exchanges (every transfer goes through the
CommLedger so Tab. 1's communication columns are produced by the training
code path itself) and delegate all client-side computation to the VFL
engine layer (``repro.engine``): gradient-clustering pseudo-labels, SDPA
estimation, and the local-SSL sessions — vmapped into one jitted program
when the party zoo is homogeneous (including few-shot's masked
fixed-shape phase ⑤', at any ragged per-party gate counts — DESIGN.md
§9), per-client Python loop otherwise (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import clustering, estimator
from repro.core.client import VFLClient, make_client, ssl_task_for
from repro.core.comm import CommLedger
from repro.core.metrics import accuracy, binary_auc
from repro.core.server import VFLServer, concat_reps
from repro.core.ssl import SSLConfig
from repro.data.vertical import VerticalSplit
from repro.models.extractors import Model


@dataclass(frozen=True)
class ProtocolConfig:
    """Frozen (use ``dataclasses.replace`` to derive variants — runner
    signatures default to None and construct a fresh instance, so no call
    ever observes another caller's mutations)."""
    client_epochs: int = 20          # E_c
    server_epochs: int = 50          # E_s
    batch_size: int = 32             # B   (paper: 32)
    client_lr: float = 0.01          # η_c (paper: 0.01)
    server_lr: float = 0.01          # η_s (paper: 0.01)
    fewshot_threshold: float = 0.9   # t in Eq. (9)
    fewshot_stochastic_gate: bool = False   # Bernoulli(p̂) sample instead of
                                     # the paper's keep-all-gated (Eq. 9)
    grad_dp_sigma: float = 0.0       # Gaussian noise on partial grads (label-DP
                                     # style defense — paper §6 compatibility)
    kmeans_iters: int = 25
    unlabeled_ratio: int = 2
    use_kernels: bool = False        # one switch: Pallas k-means + SDPA kernels
    engine_mode: str = "auto"        # "auto" | "vmap" | "python" (DESIGN.md §2)
    rep_dtype: jnp.dtype = jnp.float32

    def ssl_hparams(self) -> engine.SSLHParams:
        return engine.SSLHParams(epochs=self.client_epochs,
                                 batch_size=self.batch_size,
                                 learning_rate=self.client_lr,
                                 unlabeled_ratio=self.unlabeled_ratio)


@dataclass
class VFLResult:
    metric_name: str
    metric: float
    ledger: CommLedger
    clients: List[VFLClient]
    server: VFLServer
    diagnostics: dict = field(default_factory=dict)

    def summary_row(self) -> dict:
        """JSON-ready summary of the paper's three columns (metric, comm
        bytes, comm times) — what benchmark tables serialize per method."""
        row = {
            "metric_name": self.metric_name,
            "metric": float(self.metric),
            "comm_bytes": int(self.ledger.total_bytes()),
            "comm_times": int(self.ledger.comm_times()),
        }
        for k in ("iterations", "engine_path"):
            if k in self.diagnostics:
                row[k] = self.diagnostics[k]
        return row


# --------------------------------------------------------------------------
def _build_clients(key, split: VerticalSplit, extractors: Sequence[Model],
                   ssl_cfgs: Sequence[SSLConfig]) -> List[VFLClient]:
    clients = []
    for k_idx, (ext, cfg) in enumerate(zip(extractors, ssl_cfgs)):
        key, kc = jax.random.split(key)
        local_pool = split.unaligned[k_idx]
        clients.append(make_client(
            kc, k_idx, ext, split.num_classes,
            sample_input=split.aligned[k_idx][:2],
            ssl_cfg=cfg,
            local_data_for_mean=local_pool if local_pool.ndim == 2 else None))
    return clients


def _evaluate(server: VFLServer, clients: Sequence[VFLClient],
              split: VerticalSplit) -> tuple:
    test_reps = [c.extract(x) for c, x in zip(clients, split.test_aligned)]
    logits = server.predict_logits(test_reps)
    if split.num_classes == 2:
        scores = jax.nn.softmax(logits, axis=-1)[:, 1]
        return "auc", binary_auc(scores, split.test_labels)
    return "accuracy", accuracy(logits, split.test_labels)


def _train_clients(key, clients: Sequence[VFLClient], tasks, cfg: ProtocolConfig,
                   diagnostics: dict) -> List[VFLClient]:
    """Run every party's local SSL through the engine; record which path ran."""
    params, metrics, vmapped = engine.train_clients_ssl(
        key, tasks, cfg.ssl_hparams(), mode=cfg.engine_mode)
    diagnostics["engine_path"] = "vmap" if vmapped else "python"
    diagnostics.setdefault("ssl_metrics", []).extend(metrics)
    return [replace(c, params=p) for c, p in zip(clients, params)]


# ------------------------------------------------------------- one-shot VFL
def run_one_shot(
    key: jax.Array,
    split: VerticalSplit,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[ProtocolConfig] = None,
    ledger: Optional[CommLedger] = None,
    clients: Optional[List[VFLClient]] = None,
) -> VFLResult:
    cfg = cfg if cfg is not None else ProtocolConfig()
    ledger = ledger if ledger is not None else CommLedger()
    key, k_clients, k_srv = jax.random.split(key, 3)
    if clients is None:
        clients = _build_clients(k_clients, split, extractors, ssl_cfgs)
    server = VFLServer(num_classes=split.num_classes)

    # ① clients upload overlap representations
    reps = []
    r1 = ledger.next_round()
    for c, x_o in zip(clients, split.aligned):
        h = c.extract(x_o).astype(cfg.rep_dtype)
        ledger.log(c.index, "up", "reps_overlap", h, round=r1)
        reps.append(h)

    # ② server computes and sends partial gradients (+ class count C);
    # optional label-DP-style Gaussian noise (the paper's §6 notes such
    # defenses compose with the protocol — grad_dp_sigma exercises that)
    key, kg = jax.random.split(key)
    grads = server.partial_gradients(kg, reps, split.labels)
    if cfg.grad_dp_sigma > 0:
        noised = []
        for g in grads:
            key, kn = jax.random.split(key)
            scale = cfg.grad_dp_sigma * jnp.std(g)
            noised.append(g + scale * jax.random.normal(kn, g.shape))
        grads = noised
    r2 = ledger.next_round()
    for c, g in zip(clients, grads):
        ledger.log(c.index, "down", "partial_grads", g, round=r2)

    # ③ gradient clustering → pseudo labels;  ④ local SSL — both engine-side
    diagnostics = {"kmeans_purity": [], "ssl_metrics": []}
    key, kk, ks = jax.random.split(key, 3)
    tasks = []
    for c, g, x_o, x_u in zip(clients, grads, split.aligned, split.unaligned):
        pseudo = engine.pseudo_labels(
            jax.random.fold_in(kk, c.index), g, split.num_classes,
            cfg.kmeans_iters, use_kernels=cfg.use_kernels)
        diagnostics["kmeans_purity"].append(
            clustering.cluster_purity(pseudo, split.labels, split.num_classes))
        tasks.append(ssl_task_for(c, x_o, pseudo, x_u))
    clients = _train_clients(ks, clients, tasks, cfg, diagnostics)

    # ⑤ upload refreshed reps;  ⑥ server trains classifier
    reps = []
    r3 = ledger.next_round()
    for c, x_o in zip(clients, split.aligned):
        h = c.extract(x_o).astype(cfg.rep_dtype)
        ledger.log(c.index, "up", "reps_overlap_refreshed", h, round=r3)
        reps.append(h)
    server.train_classifier(k_srv, reps, split.labels,
                            epochs=cfg.server_epochs, batch_size=cfg.batch_size,
                            learning_rate=cfg.server_lr)

    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server, diagnostics)


def run_few_shot_finetune(
    key: jax.Array,
    split: VerticalSplit,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[ProtocolConfig] = None,
    finetune_iterations: int = 200,
) -> VFLResult:
    """Tab. 1's last row: few-shot VFL as pre-training, then end-to-end
    vanilla-VFL finetuning of the whole stack (extractors + classifier),
    sharing one ledger so the combined communication cost is visible."""
    from repro.core import baselines

    cfg = cfg if cfg is not None else ProtocolConfig()
    key, k1, k2 = jax.random.split(key, 3)
    few = run_few_shot(k1, split, extractors, ssl_cfgs, cfg)
    it_cfg = baselines.IterativeConfig(iterations=finetune_iterations,
                                       batch_size=cfg.batch_size,
                                       client_lr=cfg.client_lr / 10,
                                       server_lr=cfg.server_lr / 10)
    res = baselines.run_vanilla(k2, split, extractors, ssl_cfgs, it_cfg,
                                clients=few.clients, server=few.server,
                                ledger=few.ledger)
    res.diagnostics.update(few.diagnostics)
    res.diagnostics["fewshot_metric"] = few.metric
    return res


# ------------------------------------------------------------- few-shot VFL
def run_few_shot(
    key: jax.Array,
    split: VerticalSplit,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[ProtocolConfig] = None,
) -> VFLResult:
    cfg = cfg if cfg is not None else ProtocolConfig()
    key, k_one = jax.random.split(key)
    one = run_one_shot(k_one, split, extractors, ssl_cfgs, cfg)
    ledger, clients = one.ledger, one.clients
    server = one.server
    diagnostics = dict(one.diagnostics)

    # ①' clients upload unaligned reps alongside the refreshed overlap reps
    # (same round as ⑤ above — the ledger tags it separately but the event
    # count matches the paper's 5 comm-times; see comm.py)
    h_o_all = [c.extract(x).astype(cfg.rep_dtype) for c, x in zip(clients, split.aligned)]
    h_u_all = []
    r3 = max(e.round for e in ledger.events)   # bundled with the ⑤ upload
    for c, x_u in zip(clients, split.unaligned):
        h_u = c.extract(x_u).astype(cfg.rep_dtype)
        ledger.log(c.index, "up", "reps_unaligned", h_u, round=r3)
        h_u_all.append(h_u)

    # ②' server fits aux classifiers f_c^k and reuses the joint f_c
    key, ka = jax.random.split(key)
    server.fit_aux_classifiers(ka, h_o_all, split.labels,
                               epochs=cfg.server_epochs, batch_size=cfg.batch_size,
                               learning_rate=cfg.server_lr)

    # ③' SDPA estimation + Eq. 8-9 gating;  ④' download p̂
    probs_all = []
    diagnostics["fewshot_gate_rate"] = []
    r4 = ledger.next_round()
    for k_idx, (c, h_u) in enumerate(zip(clients, h_u_all)):
        est = engine.estimate_missing(h_u, h_o_all, k_idx,
                                      use_kernels=cfg.use_kernels)
        parts = []
        ei = 0
        for j in range(len(clients)):
            if j == k_idx:
                parts.append(h_u)
            else:
                parts.append(est[ei])
                ei += 1
        full_rep = concat_reps(parts)
        probs = estimator.infer_prob(server.aux_logits_fn(k_idx),
                                     server.joint_logits_fn(),
                                     h_u, full_rep, cfg.fewshot_threshold)
        ledger.log(c.index, "down", "pseudo_label_probs", probs, round=r4)
        probs_all.append(probs)
        diagnostics["fewshot_gate_rate"].append(float(jnp.mean(probs > 0)))

    # ⑤' clients expand the labeled set and re-run SSL (Alg. 2 l.11-19) as
    # masked fixed-shape sessions (DESIGN.md §9): every party's labeled set
    # is the full (x_o ∘ x_u) at the static capacity N_o + N_u with a
    # validity mask [1…1 ∘ gate], and the unlabeled set stays the full
    # private pool with the complementary mask — so ragged per-party gate
    # counts share one stacked shape, the vmap fast path engages under any
    # engine_mode, and an all-gated pool is simply a zero-valid unlabeled
    # mask (no row ever sits in both sets). The paper keeps *every* sample
    # passing the Eq. 9 gate (p̂ > 0); fewshot_stochastic_gate restores the
    # legacy Bernoulli(p̂) subsampling for ablations.
    tasks = []
    key, ks = jax.random.split(key)
    for c, probs, x_o, x_u in zip(clients, probs_all, split.aligned,
                                  split.unaligned):
        if cfg.fewshot_stochastic_gate:
            key, kb = jax.random.split(key)
            take = jax.random.bernoulli(
                kb, jnp.clip(probs, 0.0, 1.0)).astype(jnp.float32)
        else:
            take = (probs > 0).astype(jnp.float32)
        # pseudo labels = local model preds (for the overlap rows these agree
        # with Ŷ_o^k by construction — the local head was trained on it; the
        # gated-out x_u rows are masked and contribute nothing)
        x_lab = jnp.concatenate([x_o, x_u], axis=0)
        y_lab = jnp.concatenate([c.predict(x_o), c.predict(x_u)], axis=0)
        lab_mask = jnp.concatenate(
            [jnp.ones(x_o.shape[0], jnp.float32), take])
        tasks.append(ssl_task_for(c, x_lab, y_lab, x_u,
                                  labeled_mask=lab_mask,
                                  unlabeled_mask=1.0 - take))
        diagnostics.setdefault("fewshot_take_rate", []).append(
            float(jnp.mean(take)))
    clients = _train_clients(ks, clients, tasks, cfg, diagnostics)

    # ⑥' final upload + classifier re-fit
    reps = []
    r5 = ledger.next_round()
    for c, x_o in zip(clients, split.aligned):
        h = c.extract(x_o).astype(cfg.rep_dtype)
        ledger.log(c.index, "up", "reps_overlap_final", h, round=r5)
        reps.append(h)
    key, kf = jax.random.split(key)
    server.train_classifier(kf, reps, split.labels,
                            epochs=cfg.server_epochs, batch_size=cfg.batch_size,
                            learning_rate=cfg.server_lr)

    name, metric = _evaluate(server, clients, split)
    return VFLResult(name, metric, ledger, clients, server, diagnostics)
