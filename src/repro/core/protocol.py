"""One-shot and few-shot VFL protocol orchestration (Alg. 1 + Alg. 2).

``run_one_shot`` / ``run_few_shot`` are THIN orchestrators: they do the
ledger-tracked client↔server exchanges (every transfer goes through the
CommLedger so Tab. 1's communication columns are produced by the training
code path itself) and delegate all client-side computation to the VFL
engine layer (``repro.engine``): gradient-clustering pseudo-labels, SDPA
estimation, and the local-SSL sessions — vmapped into one jitted program
when the party zoo is homogeneous (including few-shot's masked
fixed-shape phase ⑤', at any ragged per-party gate counts — DESIGN.md
§9), per-client Python loop otherwise (DESIGN.md §2).

Both protocols are implemented once, *seed-batched* (DESIGN.md §10): the
internal ``_one_shot_seeds`` / ``_few_shot_seeds`` drive S seeds of one
scenario point through the exchanges together, folding the heavy compute
(S·K local-SSL sessions, S·K k-means runs, S server fits) into stacked
compiled programs while reproducing each seed's exact single-seed PRNG
stream host-side. The public single-seed runners are the S = 1 case of the
same code; ``run_seeds`` is the multi-seed entry point, and
``run_scenarios_seeds`` extends the very same fold along the *scenario*
axis (DESIGN.md §12): a group of shape-homogeneous scenarios flattens
scenario-major into the identical ``*_seeds`` impls, so C scenarios × S
seeds train as one stacked program under unchanged session-cache keys.
Communication is a function of shapes only, so the ledger is produced
host-side once and asserted byte-identical across seeds.
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro import engine
from repro.core import clustering
from repro.core.client import VFLClient, make_client, ssl_task_for
from repro.core.comm import CommLedger, nbytes
from repro.core.metrics import accuracy, binary_auc
from repro.core.server import (VFLServer, fit_aux_classifiers_seeds,
                               train_classifier_seeds)
from repro.core.ssl import SSLConfig
from repro.data.vertical import VerticalSplit
from repro.models.extractors import Model
from repro.scenarios.faults import (POINT_EVAL, POINT_ROUND2, POINT_SSL,
                                    POINT_UPLOAD1, POINT_UPLOAD2, FaultSpec)


@dataclass(frozen=True)
class ProtocolConfig:
    """Frozen (use ``dataclasses.replace`` to derive variants — runner
    signatures default to None and construct a fresh instance, so no call
    ever observes another caller's mutations)."""
    client_epochs: int = 20          # E_c
    server_epochs: int = 50          # E_s
    batch_size: int = 32             # B   (paper: 32)
    client_lr: float = 0.01          # η_c (paper: 0.01)
    server_lr: float = 0.01          # η_s (paper: 0.01)
    fewshot_threshold: float = 0.9   # t in Eq. (9)
    fewshot_stochastic_gate: bool = False   # Bernoulli(p̂) sample instead of
                                     # the paper's keep-all-gated (Eq. 9)
    fewshot_relabel_overlap: bool = False   # legacy phase-⑤' behavior: re-
                                     # predict the overlap rows with the
                                     # local head instead of reusing the
                                     # step-③ cluster pseudo-labels Ŷ_o^k
    grad_dp_sigma: float = 0.0       # Gaussian noise on partial grads (label-DP
                                     # style defense — paper §6 compatibility)
    kmeans_iters: int = 25
    unlabeled_ratio: int = 2
    use_kernels: bool = False        # one switch: Pallas k-means + SDPA kernels
    engine_mode: str = "auto"        # "auto" | "vmap" | "python" (DESIGN.md §2)
    mesh: object = None              # device mesh for the stacked engine axis
                                     # (DESIGN.md §14): None | device count |
                                     # jax.sharding.Mesh; None consults the
                                     # REPRO_DEVICE_COUNT env knob
    rep_dtype: jnp.dtype = jnp.float32

    def ssl_hparams(self) -> engine.SSLHParams:
        return engine.SSLHParams(epochs=self.client_epochs,
                                 batch_size=self.batch_size,
                                 learning_rate=self.client_lr,
                                 unlabeled_ratio=self.unlabeled_ratio)


@dataclass
class VFLResult:
    metric_name: str
    metric: float
    ledger: CommLedger
    clients: List[VFLClient]
    server: VFLServer
    diagnostics: dict = field(default_factory=dict)

    def summary_row(self) -> dict:
        """JSON-ready summary of the paper's three columns (metric, comm
        bytes, comm times) — built by the ONE typed row builder every
        benchmark surface shares (``repro.core.rows``, DESIGN.md §13)."""
        from repro.core import rows
        return rows.training_row(self)

    def to_artifact(self, scenario_spec, cfg=None, split=None):
        """Export this result as a deployable
        :class:`~repro.checkpoint.artifact.TrainedVFLModel` — per-party
        extractor params + apply identity, the fitted joint head, and
        provenance (DESIGN.md §13). Pass ``split`` to also bake the final
        overlap representations H_o (what serving-time missing-party
        estimation attends over, Eq. 10)."""
        from repro.checkpoint import artifact
        return artifact.from_state(self.clients, self.server, scenario_spec,
                                   cfg=cfg, metric_name=self.metric_name,
                                   metric=self.metric, split=split)


# --------------------------------------------------------------------------
def _build_clients(key, split: VerticalSplit, extractors: Sequence[Model],
                   ssl_cfgs: Sequence[SSLConfig]) -> List[VFLClient]:
    clients = []
    for k_idx, (ext, cfg) in enumerate(zip(extractors, ssl_cfgs)):
        key, kc = jax.random.split(key)
        # x̄ for the tabular augmentations (Eq. 5-6) comes from the party's
        # local rows: the private pool, or — for a full-overlap party whose
        # pool is empty — its aligned feature block (also party-local data)
        local_pool = split.unaligned[k_idx]
        if local_pool.ndim == 2 and local_pool.shape[0] == 0:
            local_pool = split.aligned[k_idx]
        clients.append(make_client(
            kc, k_idx, ext, split.num_classes,
            sample_input=split.aligned[k_idx][:2],
            ssl_cfg=cfg,
            local_data_for_mean=local_pool if local_pool.ndim == 2 else None))
    return clients


def _evaluate(server: VFLServer, clients: Sequence[VFLClient],
              split: VerticalSplit, fault: Optional[FaultSpec] = None,
              h_o_final: Optional[Sequence[jnp.ndarray]] = None,
              fkey: Optional[jax.Array] = None,
              use_kernels: bool = False) -> tuple:
    test_reps = [c.extract(x) for c, x in zip(clients, split.test_aligned)]
    if fault is not None:
        test_reps = _faulted_test_reps(test_reps, fault, h_o_final, fkey,
                                       use_kernels)
    logits = server.predict_logits(test_reps)
    if split.num_classes == 2:
        scores = jax.nn.softmax(logits, axis=-1)[:, 1]
        return "auc", binary_auc(scores, split.test_labels)
    return "accuracy", accuracy(logits, split.test_labels)


def _safe_mean(x) -> float:
    """Host-side mean that treats an empty array (e.g. a full-overlap
    party's zero-row pool) as rate 0 instead of NaN."""
    return float(jnp.mean(x)) if x.size else 0.0


def _log_seeds(ledger: CommLedger, party: int, direction: str, tag: str,
               payloads: Sequence, round: int) -> None:
    """Log ONE event for S per-seed payloads of one transfer: communication
    is a function of shapes, so the seeds must agree byte-for-byte — the
    seed-batched runs assert it at every exchange."""
    sizes = {nbytes(p) for p in payloads}
    if len(sizes) != 1:
        raise ValueError(
            f"seed-batched run broke ledger byte-identity for {tag!r}: "
            f"per-seed payload bytes {sorted(sizes)}")
    ledger.log_bytes(party, direction, tag, sizes.pop(), round=round)


# -------------------------------------------------------- fault injection
# the fault-injection PRNG stream is folded off the entry's ORIGINAL key
# with a fixed prime, disjoint from every key the protocol splits itself
_FAULT_STREAM = 15485863


def _phase_round(ledger: CommLedger, entry_ledgers) -> object:
    """Advance the round counter for one protocol phase: the shared
    prototype ledger on the fault-free path, every per-entry ledger on a
    faulted fold (healthy entries keep the prototype round sequence)."""
    if entry_ledgers is None:
        return ledger.next_round()
    return [led.next_round() for led in entry_ledgers]


def _log_phase(ledger: CommLedger, entry_ledgers, party: int,
               direction: str, tag: str, payloads: Sequence, rounds,
               skip=None) -> None:
    """Log one transfer of ``party`` across the S stacked entries.
    Fault-free folds share one prototype ledger (``_log_seeds``, with the
    byte-identity assertion); faulted folds carry one ledger PER entry so
    a dropped party's missing upload (``skip[s]``) stays entry-local
    while healthy entries' ledgers remain content-identical."""
    if entry_ledgers is None:
        _log_seeds(ledger, party, direction, tag, payloads, rounds)
        return
    for s, led in enumerate(entry_ledgers):
        if skip is not None and skip[s]:
            continue
        led.log_bytes(party, direction, tag, nbytes(payloads[s]),
                      round=rounds[s])


def _drop_skip(faults, k: int, point: int, num_seeds: int):
    """Per-entry skip flags for party k's transfer at a protocol point."""
    if faults is None:
        return None
    return [faults[s] is not None and faults[s].drops(k, point)
            for s in range(num_seeds)]


def _dp_noised(fkey: jax.Array, phase: int, party: int,
               fault: Optional[FaultSpec], arr: jnp.ndarray) -> jnp.ndarray:
    """``dp_upload`` fault: σ·std(arr) Gaussian noise on the faulted
    party's payload at the given protocol phase index. Bytes on the wire
    are unchanged — privacy costs accuracy, not communication."""
    if (fault is None or fault.kind != "dp_upload"
            or fault.party != party or fault.dp_sigma <= 0):
        return arr
    k = jax.random.fold_in(fkey, phase)
    scale = fault.dp_sigma * jnp.std(arr)
    return arr + scale * jax.random.normal(k, arr.shape).astype(arr.dtype)


def _reconstruct_dropped(reps_all, stale_all, faults, point: int,
                         use_kernels: bool) -> None:
    """Server-side Eq. 10 recovery of dropped parties' missing uploads:
    Ĥ^k = softmax(H_a H̄_aᵀ/√d) H̄_k with a the lowest-index surviving
    party, H_a its fresh upload and H̄ the last payloads the server still
    holds (DESIGN.md §16). Entries sharing (dropped, anchor) fold into ONE
    batched SDPA program (§15). A party that never uploaded (stale zeros)
    reconstructs to zeros — the same code path, degrading gracefully."""
    from repro.core import estimator
    groups: dict = {}
    for s, fa in enumerate(faults):
        if fa is None or fa.kind != "dropout":
            continue
        num_parties = len(reps_all[s])
        alive = [k for k in range(num_parties) if not fa.drops(k, point)]
        for k in range(num_parties):
            if fa.drops(k, point):
                groups.setdefault((k, alive[0]), []).append(s)
    for (k, anchor), entries in sorted(groups.items()):
        est = estimator.sdpa_transform_batched(
            jnp.stack([reps_all[s][anchor] for s in entries]),
            jnp.stack([stale_all[s][anchor] for s in entries]),
            jnp.stack([stale_all[s][k] for s in entries]),
            use_kernel=use_kernels)
        for i, s in enumerate(entries):
            reps_all[s][k] = est[i].astype(reps_all[s][k].dtype)


def _fault_step_valid(fault: Optional[FaultSpec], party: int,
                      n_labeled: int, hp, skip_all: bool) -> jnp.ndarray:
    """(n_steps,) per-step commit mask for one party's SSL session in a
    faulted fold (§16): all-zeros for a dropped / representation-only
    party, the leading ⌊fraction·epochs⌋ whole epochs for a straggler,
    all-ones otherwise. EVERY party gets a mask when the fold carries any
    fault, so the stacked session keeps one shape — the mask is data,
    never compile-time structure."""
    n_steps = engine.schedule_steps(n_labeled, hp)
    if skip_all:
        return jnp.zeros((n_steps,), jnp.float32)
    if (fault is not None and fault.kind == "straggler"
            and fault.party == party):
        steps_per_epoch = n_steps // max(hp.epochs, 1)
        active = int(hp.epochs * fault.epoch_fraction) * steps_per_epoch
        return (jnp.arange(n_steps) < active).astype(jnp.float32)
    return jnp.ones((n_steps,), jnp.float32)


def _faulted_test_reps(test_reps, fault: FaultSpec, h_o_final, fkey,
                       use_kernels: bool):
    """Degraded-serving view of the test forward (§16): a dropped party's
    test representations are Eq. 10-reconstructed from the final overlap
    reps (zero-imputed when no estimator memory exists — the iterative
    baselines), and a dp_upload party's payload carries the same σ·std
    noise as its training uploads."""
    from repro.core import estimator
    reps = list(test_reps)
    num_parties = len(reps)
    if fault.kind == "dp_upload":
        if fkey is not None and fault.party < num_parties:
            reps[fault.party] = _dp_noised(fkey, 5, fault.party, fault,
                                           reps[fault.party])
        return reps
    if fault.kind != "dropout":
        return reps
    alive = [j for j in range(num_parties)
             if not fault.drops(j, POINT_EVAL)]
    for k in range(num_parties):
        if fault.drops(k, POINT_EVAL):
            if h_o_final is None:
                reps[k] = jnp.zeros_like(reps[k])
            else:
                reps[k] = estimator.sdpa_transform(
                    reps[alive[0]], h_o_final[alive[0]], h_o_final[k],
                    use_kernel=use_kernels).astype(reps[k].dtype)
    return reps


def _fault_diags(fault: Optional[FaultSpec], num_parties: int,
                 metric: float) -> dict:
    """Per-entry fault diagnostics every faulted row reports (rows.py)."""
    d = {"fault_kind": fault.kind if fault is not None else "none",
         "parties_survived": (fault.parties_survived(num_parties)
                              if fault is not None else num_parties),
         "degraded_metric": float(metric)}
    if fault is not None and fault.kind == "dropout":
        d["fault_stage"] = fault.stage
    return d


def fewshot_phase5_labels(client: VFLClient, x_o: jnp.ndarray,
                          x_u: jnp.ndarray, pseudo_overlap: jnp.ndarray,
                          relabel_overlap: bool = False) -> jnp.ndarray:
    """Labels of the padded phase-⑤' labeled set ``x_o ∘ x_u`` (Alg. 2
    l.11-19): the overlap rows reuse the step-③ gradient-cluster
    pseudo-labels Ŷ_o^k — the local head may drift off them during SSL, so
    re-predicting is NOT guaranteed to agree — and the pool rows take the
    local model's predictions (their contribution is masked by the Eq. 9
    gate). ``relabel_overlap`` restores the legacy re-prediction of the
    overlap rows for ablations."""
    y_o = (client.predict(x_o) if relabel_overlap
           else pseudo_overlap.astype(jnp.int32))
    return jnp.concatenate([y_o, client.predict(x_u)], axis=0)


# ------------------------------------------------------------- one-shot VFL
def _one_shot_seeds(
    keys: Sequence[jax.Array],
    splits: Sequence[VerticalSplit],
    extractors: Sequence[Sequence[Model]],
    ssl_cfgs: Sequence[Sequence[SSLConfig]],
    cfg: Optional[ProtocolConfig] = None,
    ledger: Optional[CommLedger] = None,
    clients_per_seed: Optional[Sequence[Optional[List[VFLClient]]]] = None,
    final_reps_out: Optional[list] = None,
    faults: Optional[Sequence[Optional[FaultSpec]]] = None,
    ledgers: Optional[Sequence[CommLedger]] = None,
) -> List[VFLResult]:
    """Alg. 1 over S seeds at once. Per-seed PRNG streams are split exactly
    like the historical single-seed runner's (S = 1 *is* the single-seed
    runner); the heavy stages — step-③ k-means, step-④ local SSL, step-⑥
    classifier fit — execute seed-batched (DESIGN.md §10). All results
    share ``ledger``; multi-seed callers copy it per result.
    ``final_reps_out`` (if given) receives the step-⑤ refreshed overlap
    reps per seed, so few-shot's ①' needn't re-extract them.

    ``faults`` (one optional :class:`FaultSpec` per entry, DESIGN.md §16)
    switches the fold to per-entry ``ledgers``: a dropped party's missing
    uploads are skipped entry-locally and its H_o^k reconstructed by the
    Eq. 10 estimator, stragglers/representation-only parties ride the
    §9 mask machinery as ``step_valid`` data, dp_upload entries noise
    their payloads — shapes never change, so the faulted fold runs the
    SAME stacked programs under unchanged session-cache keys."""
    cfg = cfg if cfg is not None else ProtocolConfig()
    ledger = ledger if ledger is not None else CommLedger()
    num_seeds = len(keys)
    num_parties = len(splits[0].aligned)
    mesh = engine.resolve_mesh(cfg.mesh)
    if faults is not None and len(faults) != num_seeds:
        raise ValueError("faults needs one entry (FaultSpec or None) per "
                         "stacked seed/scenario entry")
    faulted = faults is not None
    if not faulted:
        faults = [None] * num_seeds
    entry_ledgers = fkeys = None
    if faulted:
        entry_ledgers = (list(ledgers) if ledgers is not None
                         else [CommLedger() for _ in range(num_seeds)])
        fkeys = [jax.random.fold_in(keys[s], _FAULT_STREAM)
                 for s in range(num_seeds)]

    st_keys, k_srvs, clients_all, servers = [], [], [], []
    for s in range(num_seeds):
        key, k_clients, k_srv = jax.random.split(keys[s], 3)
        given = clients_per_seed[s] if clients_per_seed is not None else None
        clients = (given if given is not None else
                   _build_clients(k_clients, splits[s], extractors[s],
                                  ssl_cfgs[s]))
        st_keys.append(key)
        k_srvs.append(k_srv)
        clients_all.append(clients)
        servers.append(VFLServer(num_classes=splits[s].num_classes))

    # ① clients upload overlap representations. A party dropped before
    # this point never shows up: the server zero-imputes its H_o^k slot
    # (fixed shapes — the fold never re-compiles) and no event is logged.
    reps_all = [[c.extract(x_o).astype(cfg.rep_dtype)
                 for c, x_o in zip(clients_all[s], splits[s].aligned)]
                for s in range(num_seeds)]
    if faulted:
        for s, fa in enumerate(faults):
            if fa is None:
                continue
            for k in range(num_parties):
                if fa.drops(k, POINT_UPLOAD1):
                    reps_all[s][k] = jnp.zeros_like(reps_all[s][k])
                else:
                    reps_all[s][k] = _dp_noised(fkeys[s], 1, k, fa,
                                                reps_all[s][k])
    r1 = _phase_round(ledger, entry_ledgers)
    for k in range(num_parties):
        _log_phase(ledger, entry_ledgers, k, "up", "reps_overlap",
                   [reps_all[s][k] for s in range(num_seeds)], r1,
                   skip=_drop_skip(faults if faulted else None, k,
                                   POINT_UPLOAD1, num_seeds))
    # the server's last-seen view of every party, AFTER imputation/noise —
    # what Eq. 10 reconstruction attends over at step ⑤
    stale_reps = ([list(reps) for reps in reps_all] if faulted else None)

    # ② server computes and sends partial gradients (+ class count C);
    # optional label-DP-style Gaussian noise (the paper's §6 notes such
    # defenses compose with the protocol — grad_dp_sigma exercises that)
    grads_all = []
    for s in range(num_seeds):
        st_keys[s], kg = jax.random.split(st_keys[s])
        grads = servers[s].partial_gradients(kg, reps_all[s],
                                             splits[s].labels)
        if cfg.grad_dp_sigma > 0:
            noised = []
            for g in grads:
                st_keys[s], kn = jax.random.split(st_keys[s])
                scale = cfg.grad_dp_sigma * jnp.std(g)
                noised.append(g + scale * jax.random.normal(kn, g.shape))
            grads = noised
        grads_all.append(grads)
    r2 = _phase_round(ledger, entry_ledgers)
    for k in range(num_parties):
        _log_phase(ledger, entry_ledgers, k, "down", "partial_grads",
                   [grads_all[s][k] for s in range(num_seeds)], r2,
                   skip=_drop_skip(faults if faulted else None, k,
                                   POINT_SSL, num_seeds))

    # ③ gradient clustering → pseudo labels;  ④ local SSL — both engine-
    # side and seed-batched: the S·K gradient matrices cluster in one
    # vmapped k-means, the S·K SSL sessions fold into one stacked program
    diags = [{"kmeans_purity": [], "ssl_metrics": [],
              "seed_fold": num_seeds} for _ in range(num_seeds)]
    kss = []
    flat_kmeans_keys, flat_grads = [], []
    for s in range(num_seeds):
        st_keys[s], kk, ks = jax.random.split(st_keys[s], 3)
        kss.append(ks)
        flat_kmeans_keys.extend(jax.random.fold_in(kk, c.index)
                                for c in clients_all[s])
        flat_grads.extend(grads_all[s])
    km_info: dict = {}
    flat_pseudo = engine.pseudo_labels_seeds(
        flat_kmeans_keys, flat_grads, splits[0].num_classes,
        cfg.kmeans_iters, use_kernels=cfg.use_kernels, mesh=mesh,
        info=km_info)
    pseudo_all = engine.unflatten_seed_results(flat_pseudo, num_seeds,
                                               num_parties)
    for s in range(num_seeds):
        # the k-means fold width actually run (S·K on the folded path, 1 on
        # the ragged-shape fallback) — kernel and jnp routes alike
        diags[s]["kernel_fold"] = km_info.get("fold", 1)
        if "fallback" in km_info:
            diags[s]["kernel_fallback"] = km_info["fallback"]
    tasks_per_seed = []
    hp = cfg.ssl_hparams()
    for s in range(num_seeds):
        tasks = []
        fa = faults[s]
        for c, pseudo, x_o, x_u in zip(clients_all[s], pseudo_all[s],
                                       splits[s].aligned,
                                       splits[s].unaligned):
            diags[s]["kmeans_purity"].append(clustering.cluster_purity(
                pseudo, splits[s].labels, splits[s].num_classes))
            # faulted folds give EVERY party a per-step commit mask (§16):
            # all-ones healthy, truncated straggler, all-zero dropped /
            # representation-only — mask as data, one stacked shape
            sv = (_fault_step_valid(fa, c.index, x_o.shape[0], hp,
                                    skip_all=(fa is not None
                                              and fa.skips_ssl(c.index)))
                  if faulted else None)
            # equal-shape overlap variants pad x_o to a fixed capacity; the
            # split's validity mask zeroes the padded rows out of the loss
            tasks.append(ssl_task_for(c, x_o, pseudo, x_u,
                                      labeled_mask=splits[s].aligned_mask,
                                      step_valid=sv))
        diags[s]["pseudo_labels"] = pseudo_all[s]   # Ŷ_o^k — few-shot ⑤'
        tasks_per_seed.append(tasks)                # reuses them (Alg. 2)
    params_all, metrics_all, paths = engine.train_clients_ssl_seeds(
        kss, tasks_per_seed, cfg.ssl_hparams(), mode=cfg.engine_mode,
        mesh=mesh)
    for s in range(num_seeds):
        diags[s]["engine_path"] = paths[s]
        diags[s]["device_fold"] = (engine.device_fold(mesh)
                                   if paths[s] == "vmap" else 1)
        diags[s]["ssl_metrics"].extend(metrics_all[s])
        clients_all[s] = [replace(c, params=p)
                          for c, p in zip(clients_all[s], params_all[s])]

    # ⑤ upload refreshed reps;  ⑥ server trains classifier (seed-batched).
    # Parties dropped by now upload nothing: the server reconstructs their
    # slot via Eq. 10 attention from the lowest-index survivor's refreshed
    # upload over the stale step-① payloads it still holds (§16).
    reps_all = [[c.extract(x_o).astype(cfg.rep_dtype)
                 for c, x_o in zip(clients_all[s], splits[s].aligned)]
                for s in range(num_seeds)]
    if faulted:
        for s, fa in enumerate(faults):
            if fa is None:
                continue
            for k in range(num_parties):
                reps_all[s][k] = _dp_noised(fkeys[s], 2, k, fa,
                                            reps_all[s][k])
        _reconstruct_dropped(reps_all, stale_reps, faults, POINT_UPLOAD2,
                             cfg.use_kernels)
    r3 = _phase_round(ledger, entry_ledgers)
    for k in range(num_parties):
        _log_phase(ledger, entry_ledgers, k, "up", "reps_overlap_refreshed",
                   [reps_all[s][k] for s in range(num_seeds)], r3,
                   skip=_drop_skip(faults if faulted else None, k,
                                   POINT_UPLOAD2, num_seeds))
    train_classifier_seeds(k_srvs, servers, reps_all,
                           [sp.labels for sp in splits],
                           epochs=cfg.server_epochs,
                           batch_size=cfg.batch_size,
                           learning_rate=cfg.server_lr, mesh=mesh)
    if final_reps_out is not None:
        final_reps_out.extend(reps_all)

    results = []
    for s in range(num_seeds):
        name, metric = _evaluate(
            servers[s], clients_all[s], splits[s], fault=faults[s],
            h_o_final=reps_all[s] if faulted else None,
            fkey=fkeys[s] if faulted else None,
            use_kernels=cfg.use_kernels)
        if faulted:
            diags[s].update(_fault_diags(faults[s], num_parties, metric))
        results.append(VFLResult(name, metric,
                                 entry_ledgers[s] if faulted else ledger,
                                 clients_all[s], servers[s], diags[s]))
    return results


def run_one_shot(
    key: jax.Array,
    split: VerticalSplit,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[ProtocolConfig] = None,
    ledger: Optional[CommLedger] = None,
    clients: Optional[List[VFLClient]] = None,
    fault: Optional[FaultSpec] = None,
) -> VFLResult:
    return _one_shot_seeds([key], [split], [extractors], [ssl_cfgs], cfg,
                           ledger=ledger, clients_per_seed=[clients],
                           faults=None if fault is None else [fault])[0]


def _few_shot_finetune_seeds(
    keys: Sequence[jax.Array],
    splits: Sequence[VerticalSplit],
    extractors: Sequence[Sequence[Model]],
    ssl_cfgs: Sequence[Sequence[SSLConfig]],
    cfg: Optional[ProtocolConfig] = None,
    finetune_iterations: int = 200,
    faults: Optional[Sequence[Optional[FaultSpec]]] = None,
) -> List[VFLResult]:
    """Tab. 1's last row over S seeds at once: the seed-batched few-shot
    pass hands its per-seed output state (trained clients + fitted server)
    straight to the seed-batched vanilla finetune — the folded few-shot
    carry chains into the folded finetune session with no per-seed loop in
    between, and the shared ledger accumulates both stages' transfers."""
    from repro.core import baselines

    if faults is not None and any(fa is not None for fa in faults):
        raise ValueError(
            "few_shot_finetune does not support fault injection: the "
            "chained finetune stage is the iterative round loop — model "
            "its dropout cost with run_vanilla_seeds(faults=...) instead")
    cfg = cfg if cfg is not None else ProtocolConfig()
    k1s, k2s = [], []
    for s in range(len(keys)):
        key, k1, k2 = jax.random.split(keys[s], 3)
        k1s.append(k1)
        k2s.append(k2)
    fews = _few_shot_seeds(k1s, splits, extractors, ssl_cfgs, cfg)
    it_cfg = baselines.IterativeConfig(iterations=finetune_iterations,
                                       batch_size=cfg.batch_size,
                                       client_lr=cfg.client_lr / 10,
                                       server_lr=cfg.server_lr / 10,
                                       mesh=cfg.mesh)
    results = baselines.run_vanilla_seeds(
        k2s, splits, extractors, ssl_cfgs, it_cfg,
        clients_per_seed=[f.clients for f in fews],
        servers=[f.server for f in fews],
        ledger=fews[0].ledger)       # one shared ledger spans both stages
    for res, few in zip(results, fews):
        res.diagnostics.update(few.diagnostics)
        res.diagnostics["fewshot_metric"] = few.metric
    return results


def run_few_shot_finetune(
    key: jax.Array,
    split: VerticalSplit,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[ProtocolConfig] = None,
    finetune_iterations: int = 200,
) -> VFLResult:
    """Tab. 1's last row: few-shot VFL as pre-training, then end-to-end
    vanilla-VFL finetuning of the whole stack (extractors + classifier),
    sharing one ledger so the combined communication cost is visible."""
    return _few_shot_finetune_seeds(
        [key], [split], [extractors], [ssl_cfgs], cfg,
        finetune_iterations=finetune_iterations)[0]


# ------------------------------------------------------------- few-shot VFL
def _few_shot_seeds(
    keys: Sequence[jax.Array],
    splits: Sequence[VerticalSplit],
    extractors: Sequence[Sequence[Model]],
    ssl_cfgs: Sequence[Sequence[SSLConfig]],
    cfg: Optional[ProtocolConfig] = None,
    ledger: Optional[CommLedger] = None,
    faults: Optional[Sequence[Optional[FaultSpec]]] = None,
) -> List[VFLResult]:
    """Alg. 2 over S seeds at once, continuing from the seed-batched
    one-shot pass: the aux-classifier fits, the ③' SDPA estimation +
    Eq. 8-9 gating (``engine.fewshot_probs_seeds`` — one batched program
    per party over the stacked seed axis, DESIGN.md §15), the masked
    phase-⑤' SSL sessions, and the final classifier re-fit all execute
    seed-batched with the exact single-seed key discipline.

    ``faults`` (DESIGN.md §16) threads straight through the one-shot pass
    (per-entry ledgers, same objects) and then governs round 2: a dropped
    party skips every round-2 event — its final upload is Eq. 10-
    reconstructed from the surviving anchor over the ⑤-era overlap view —
    while stragglers/representation-only parties re-enter ⑤' as
    ``step_valid`` masks on the SAME stacked session shapes."""
    cfg = cfg if cfg is not None else ProtocolConfig()
    ledger = ledger if ledger is not None else CommLedger()
    num_seeds = len(keys)
    num_parties = len(splits[0].aligned)
    mesh = engine.resolve_mesh(cfg.mesh)
    if faults is not None and len(faults) != num_seeds:
        raise ValueError("faults needs one entry (FaultSpec or None) per "
                         "stacked seed/scenario entry")
    faulted = faults is not None
    if not faulted:
        faults = [None] * num_seeds
    entry_ledgers = fkeys = None
    if faulted:
        entry_ledgers = [CommLedger() for _ in range(num_seeds)]
        fkeys = [jax.random.fold_in(keys[s], _FAULT_STREAM)
                 for s in range(num_seeds)]

    st_keys, k_ones = [], []
    for s in range(num_seeds):
        key, k_one = jax.random.split(keys[s])
        st_keys.append(key)
        k_ones.append(k_one)
    h_o_all: list = []
    ones = _one_shot_seeds(k_ones, splits, extractors, ssl_cfgs, cfg,
                           ledger=ledger, final_reps_out=h_o_all,
                           faults=faults if faulted else None,
                           ledgers=entry_ledgers)
    clients_all = [r.clients for r in ones]
    servers = [r.server for r in ones]
    diags = [dict(r.diagnostics) for r in ones]

    # ①' clients upload unaligned reps alongside the refreshed overlap reps
    # (h_o_all IS the step-⑤ upload — same params, same dtype — and shares
    # its round: the ledger tags the unaligned payload separately but the
    # event count matches the paper's 5 comm-times; see comm.py)
    h_u_all = [[c.extract(x).astype(cfg.rep_dtype)
                for c, x in zip(clients_all[s], splits[s].unaligned)]
               for s in range(num_seeds)]
    if faulted:
        for s, fa in enumerate(faults):
            if fa is None:
                continue
            for k in range(num_parties):
                h_u_all[s][k] = _dp_noised(fkeys[s], 3, k, fa,
                                           h_u_all[s][k])
    if entry_ledgers is None:   # bundled with the ⑤ upload
        r3 = max(e.round for e in ledger.events)
    else:
        r3 = [max(e.round for e in led.events) for led in entry_ledgers]
    for k in range(num_parties):
        _log_phase(ledger, entry_ledgers, k, "up", "reps_unaligned",
                   [h_u_all[s][k] for s in range(num_seeds)], r3,
                   skip=_drop_skip(faults if faulted else None, k,
                                   POINT_ROUND2, num_seeds))

    # ②' server fits aux classifiers f_c^k (seed-batched) and reuses the
    # joint f_c
    kas = []
    for s in range(num_seeds):
        st_keys[s], ka = jax.random.split(st_keys[s])
        kas.append(ka)
    fit_aux_classifiers_seeds(kas, servers, h_o_all,
                              [sp.labels for sp in splits],
                              epochs=cfg.server_epochs,
                              batch_size=cfg.batch_size,
                              learning_rate=cfg.server_lr, mesh=mesh)

    # ③' SDPA estimation + Eq. 8-9 gating;  ④' download p̂ — seed-batched
    # (DESIGN.md §15): per party, the S estimations + gates fold over the
    # stacked seed axis (one batched SDPA program per missing party — ONE
    # Pallas grid launch under cfg.use_kernels — and one vmapped gate
    # session); the single-seed path is the width-1 case of the same code
    # under the same session-cache keys.
    probs_all = [[] for _ in range(num_seeds)]
    for s in range(num_seeds):
        diags[s]["fewshot_gate_rate"] = []
        diags[s]["sdpa_fold"] = num_seeds
    h_o_stacks = [jnp.stack([h_o_all[s][j] for s in range(num_seeds)])
                  for j in range(num_parties)]
    r4 = _phase_round(ledger, entry_ledgers)
    for k_idx in range(num_parties):
        h_u_stack = jnp.stack([h_u_all[s][k_idx] for s in range(num_seeds)])
        probs_stack = engine.fewshot_probs_seeds(
            servers, k_idx, h_u_stack, h_o_stacks, cfg.fewshot_threshold,
            use_kernels=cfg.use_kernels, mesh=mesh)
        for s in range(num_seeds):
            probs_all[s].append(probs_stack[s])
            diags[s]["fewshot_gate_rate"].append(
                _safe_mean(probs_stack[s] > 0))
        _log_phase(ledger, entry_ledgers, k_idx, "down",
                   "pseudo_label_probs",
                   [probs_all[s][k_idx] for s in range(num_seeds)], r4,
                   skip=_drop_skip(faults if faulted else None, k_idx,
                                   POINT_ROUND2, num_seeds))

    # ⑤' clients expand the labeled set and re-run SSL (Alg. 2 l.11-19) as
    # masked fixed-shape sessions (DESIGN.md §9): every party's labeled set
    # is the full (x_o ∘ x_u) at the static capacity N_o + N_u with a
    # validity mask [1…1 ∘ gate], and the unlabeled set stays the full
    # private pool with the complementary mask — so ragged per-party gate
    # counts share one stacked shape, the vmap fast path engages under any
    # engine_mode, and an all-gated pool is simply a zero-valid unlabeled
    # mask (no row ever sits in both sets). The paper keeps *every* sample
    # passing the Eq. 9 gate (p̂ > 0); fewshot_stochastic_gate restores the
    # legacy Bernoulli(p̂) subsampling for ablations. Overlap rows keep the
    # step-③ cluster pseudo-labels Ŷ_o^k (``fewshot_phase5_labels``).
    kss = []
    for s in range(num_seeds):
        st_keys[s], ks = jax.random.split(st_keys[s])
        kss.append(ks)
    tasks_per_seed = []
    hp = cfg.ssl_hparams()
    for s in range(num_seeds):
        tasks = []
        fa = faults[s]
        for c, probs, pseudo, x_o, x_u in zip(
                clients_all[s], probs_all[s], diags[s]["pseudo_labels"],
                splits[s].aligned, splits[s].unaligned):
            if cfg.fewshot_stochastic_gate:
                st_keys[s], kb = jax.random.split(st_keys[s])
                take = jax.random.bernoulli(
                    kb, jnp.clip(probs, 0.0, 1.0)).astype(jnp.float32)
            else:
                take = (probs > 0).astype(jnp.float32)
            # a party absent from round 2 never received p̂: nothing gates
            # in, and its ⑤' session commits zero steps (step_valid below)
            skip_r2 = (fa is not None
                       and (fa.skips_ssl(c.index)
                            or fa.drops(c.index, POINT_ROUND2)))
            if skip_r2:
                take = jnp.zeros_like(take)
            x_lab = jnp.concatenate([x_o, x_u], axis=0)
            y_lab = fewshot_phase5_labels(c, x_o, x_u, pseudo,
                                          cfg.fewshot_relabel_overlap)
            # an equal-shape overlap variant's padded x_o rows stay invalid
            # in phase ⑤' too: the overlap part of the mask is the split's
            # validity mask instead of all-ones
            o_mask = (jnp.ones(x_o.shape[0], jnp.float32)
                      if splits[s].aligned_mask is None
                      else splits[s].aligned_mask.astype(jnp.float32))
            lab_mask = jnp.concatenate([o_mask, take])
            sv = (_fault_step_valid(fa, c.index, x_lab.shape[0], hp,
                                    skip_all=skip_r2)
                  if faulted else None)
            tasks.append(ssl_task_for(c, x_lab, y_lab, x_u,
                                      labeled_mask=lab_mask,
                                      unlabeled_mask=1.0 - take,
                                      step_valid=sv))
            diags[s].setdefault("fewshot_take_rate", []).append(
                _safe_mean(take))
        tasks_per_seed.append(tasks)
    params_all, metrics_all, paths = engine.train_clients_ssl_seeds(
        kss, tasks_per_seed, cfg.ssl_hparams(), mode=cfg.engine_mode,
        mesh=mesh)
    for s in range(num_seeds):
        diags[s]["engine_path"] = paths[s]
        diags[s]["device_fold"] = (engine.device_fold(mesh)
                                   if paths[s] == "vmap" else 1)
        diags[s].setdefault("ssl_metrics", []).extend(metrics_all[s])
        clients_all[s] = [replace(c, params=p)
                          for c, p in zip(clients_all[s], params_all[s])]

    # ⑥' final upload + classifier re-fit (seed-batched). Round-2-dropped
    # parties upload nothing; their slot is Eq. 10-reconstructed from the
    # anchor's final upload over the ⑤-era overlap view (h_o_all).
    reps_all = [[c.extract(x_o).astype(cfg.rep_dtype)
                 for c, x_o in zip(clients_all[s], splits[s].aligned)]
                for s in range(num_seeds)]
    if faulted:
        for s, fa in enumerate(faults):
            if fa is None:
                continue
            for k in range(num_parties):
                reps_all[s][k] = _dp_noised(fkeys[s], 4, k, fa,
                                            reps_all[s][k])
        _reconstruct_dropped(reps_all, h_o_all, faults, POINT_ROUND2,
                             cfg.use_kernels)
    r5 = _phase_round(ledger, entry_ledgers)
    for k in range(num_parties):
        _log_phase(ledger, entry_ledgers, k, "up", "reps_overlap_final",
                   [reps_all[s][k] for s in range(num_seeds)], r5,
                   skip=_drop_skip(faults if faulted else None, k,
                                   POINT_ROUND2, num_seeds))
    kfs = []
    for s in range(num_seeds):
        st_keys[s], kf = jax.random.split(st_keys[s])
        kfs.append(kf)
    train_classifier_seeds(kfs, servers, reps_all,
                           [sp.labels for sp in splits],
                           epochs=cfg.server_epochs,
                           batch_size=cfg.batch_size,
                           learning_rate=cfg.server_lr, mesh=mesh)

    results = []
    for s in range(num_seeds):
        name, metric = _evaluate(
            servers[s], clients_all[s], splits[s], fault=faults[s],
            h_o_final=reps_all[s] if faulted else None,
            fkey=fkeys[s] if faulted else None,
            use_kernels=cfg.use_kernels)
        if faulted:
            diags[s].update(_fault_diags(faults[s], num_parties, metric))
        results.append(VFLResult(name, metric,
                                 entry_ledgers[s] if faulted else ledger,
                                 clients_all[s], servers[s], diags[s]))
    return results


def run_few_shot(
    key: jax.Array,
    split: VerticalSplit,
    extractors: Sequence[Model],
    ssl_cfgs: Sequence[SSLConfig],
    cfg: Optional[ProtocolConfig] = None,
    fault: Optional[FaultSpec] = None,
) -> VFLResult:
    return _few_shot_seeds([key], [split], [extractors], [ssl_cfgs], cfg,
                           faults=None if fault is None else [fault])[0]


# ---------------------------------------------------- multi-seed orchestrator
def _splits_are_homogeneous(splits: Sequence[VerticalSplit]) -> bool:
    """True when every seed's split shares all shapes and the class count —
    the precondition of seed-batched execution (one scenario point's seeds
    satisfy it by construction; communication is then seed-invariant)."""
    s0 = splits[0]

    def sig(sp):
        mask = getattr(sp, "aligned_mask", None)
        return (tuple(x.shape for x in sp.aligned),
                tuple(x.shape for x in sp.unaligned),
                tuple(x.shape for x in sp.test_aligned),
                sp.labels.shape, sp.test_labels.shape, sp.num_classes,
                None if mask is None else tuple(mask.shape))

    return all(sig(sp) == sig(s0) for sp in splits[1:])


def _copy_ledger(ledger: CommLedger) -> CommLedger:
    return CommLedger(events=list(ledger.events),
                      _round_counter=ledger._round_counter)


def _assert_ledgers_identical(ledgers: Sequence[CommLedger]) -> None:
    l0 = ledgers[0]
    for i, led in enumerate(ledgers[1:], start=1):
        if (led.total_bytes() != l0.total_bytes()
                or led.comm_times() != l0.comm_times()
                or led.by_tag() != l0.by_tag()):
            raise ValueError(
                f"seed {i} produced a different communication ledger than "
                f"seed 0 — multi-seed runs of one scenario point must be "
                f"byte-identical ({led.total_bytes()} vs {l0.total_bytes()} "
                f"bytes)")


def _run_one_scenario_seeds(runner, impl, keys, splits, extractors, ssl_cfgs,
                            cfg, faults=None, **runner_kwargs
                            ) -> List[VFLResult]:
    """One scenario's S seeds when the cross-scenario fold doesn't apply:
    seed-batched when the runner has a registered ``*_seeds`` impl and the
    seeds share one shape, else a per-seed loop over the runner's cached
    sessions (with the ledger byte-identity asserted post hoc)."""
    num_seeds = len(keys)
    if impl is not None and _splits_are_homogeneous(splits):
        kw = dict(runner_kwargs)
        if faults is not None:
            kw["faults"] = list(faults)
        results = impl(list(keys), list(splits), list(extractors),
                       list(ssl_cfgs), cfg, **kw)
        if num_seeds > 1:       # the shared prototype ledger → per-seed copies
            for res in results:
                res.ledger = _copy_ledger(res.ledger)
    else:
        results = [runner(k, sp, ex, sc, cfg,
                          **(runner_kwargs if faults is None
                             else {**runner_kwargs, "fault": faults[i]}))
                   for i, (k, sp, ex, sc) in enumerate(zip(
                       keys, splits, extractors, ssl_cfgs))]
        _assert_ledgers_identical([r.ledger for r in results])
    for res in results:
        res.diagnostics.setdefault("scenario_fold", 1)
        res.diagnostics.setdefault("device_fold", 1)
    return results


def run_scenarios_seeds(
    runner,
    keys: Sequence[Sequence[jax.Array]],
    splits: Sequence[Sequence[VerticalSplit]],
    extractors: Sequence[Sequence[Sequence[Model]]],
    ssl_cfgs: Sequence[Sequence[Sequence[SSLConfig]]],
    cfg=None,
    **runner_kwargs,
) -> List[List[VFLResult]]:
    """Run C grouped scenarios × S seeds as ONE folded sweep (DESIGN.md
    §12). Arguments are rectangular C×S grids (``keys[c][s]`` …); returns
    the results on the same grid.

    The batch axis of every seed-batched runner is *anonymous* — nothing
    in the stacked programs distinguishes "seed s" from "scenario c, seed
    s" — so a group of scenarios whose splits share one shape signature
    flattens scenario-major into the registered ``*_seeds`` impl exactly
    like extra seeds: one vmapped S·C·K local-SSL session, one folded
    step-③ k-means, seed×scenario-batched server fits (or, for the
    iterative baselines, one ``vmap``-of-scan over S·C stacked carries).
    Session-cache keys never contain the batch width, so a C ≥ 2 fold
    against a warm single-scenario cache adds ZERO fresh session builds
    (tests/test_scenario_batched.py pins this, along with fold ≡
    per-scenario-loop parity at 1e-5).

    Each result's ``diagnostics["seed_fold"]`` / ``["scenario_fold"]``
    record the fold actually run (S and C on the folded path). Grids whose
    flat splits are NOT shape-homogeneous — or unregistered runners — fall
    back to the per-scenario path (``scenario_fold`` 1), which itself
    seed-batches where it can; :func:`run_seeds` is precisely the C = 1
    case. Ledgers are per-(scenario, seed) copies; byte-identity across
    the whole flat batch is asserted at every exchange on the folded path.
    Per-seed *state* kwargs are rejected exactly as in :func:`run_seeds`.
    """
    from repro.core import runners as runner_registry  # deferred: registry
                                                       # imports this module
    num_scenarios = len(keys)
    if not (len(splits) == len(extractors) == len(ssl_cfgs)
            == num_scenarios):
        raise ValueError("run_scenarios_seeds needs one per-seed list of "
                         "keys / splits / extractor stacks / ssl-cfg lists "
                         "per scenario")
    if num_scenarios == 0:
        return []
    num_seeds = len(keys[0])
    for c in range(num_scenarios):
        if not (len(keys[c]) == len(splits[c]) == len(extractors[c])
                == len(ssl_cfgs[c]) == num_seeds):
            raise ValueError(
                "run_scenarios_seeds needs a rectangular C×S grid: every "
                "scenario must carry the same per-seed list lengths")
    entry = runner_registry.resolve(runner)
    runner_registry.reject_stateful_kwargs("run_scenarios_seeds",
                                           runner_kwargs, entry)
    impl = entry.seeds_impl if entry is not None else None
    # faults is a C×S grid of Optional[FaultSpec] mirroring the data grids
    # (DESIGN.md §16); it flattens scenario-major with them, as per-entry
    # DATA — fold signatures and session-cache keys never see it
    faults = runner_kwargs.pop("faults", None)
    if faults is not None:
        if (len(faults) != num_scenarios
                or any(len(row) != num_seeds for row in faults)):
            raise ValueError("faults must mirror the C×S grid: one entry "
                             "(FaultSpec or None) per scenario per seed")
        if not any(fa is not None for row in faults for fa in row):
            faults = None
    flat_splits = [sp for row in splits for sp in row]
    if impl is not None and num_scenarios > 1 \
            and _splits_are_homogeneous(flat_splits):
        flat_keys = [k for row in keys for k in row]
        flat_ext = [e for row in extractors for e in row]
        flat_ssl = [s for row in ssl_cfgs for s in row]
        kw = dict(runner_kwargs)
        if faults is not None:
            kw["faults"] = [fa for row in faults for fa in row]
        results = impl(flat_keys, flat_splits, flat_ext, flat_ssl, cfg,
                       **kw)
        if len(results) > 1:    # the shared prototype ledger → per-entry copies
            for res in results:
                res.ledger = _copy_ledger(res.ledger)
        for res in results:
            # the impl counted the flat width as its seed fold; report the
            # grid's true factorization instead
            res.diagnostics["seed_fold"] = num_seeds
            res.diagnostics["scenario_fold"] = num_scenarios
        return [results[c * num_seeds:(c + 1) * num_seeds]
                for c in range(num_scenarios)]
    return [_run_one_scenario_seeds(runner, impl, list(keys[c]),
                                    list(splits[c]), list(extractors[c]),
                                    list(ssl_cfgs[c]), cfg,
                                    faults=(None if faults is None
                                            else list(faults[c])),
                                    **runner_kwargs)
            for c in range(num_scenarios)]


def run_seeds(
    runner,
    keys: Sequence[jax.Array],
    splits: Sequence[VerticalSplit],
    extractors: Sequence[Sequence[Model]],
    ssl_cfgs: Sequence[Sequence[SSLConfig]],
    cfg=None,
    **runner_kwargs,
) -> List[VFLResult]:
    """Run one scenario point over S seeds (DESIGN.md §10-11) — the C = 1
    case of :func:`run_scenarios_seeds`, under the same session-cache keys.

    EVERY registered runner executes seed-BATCHED: the protocol runners
    (``run_one_shot`` / ``run_few_shot`` / ``run_few_shot_finetune``) fold
    S·K local-SSL sessions into one stacked vmapped program with the
    k-means and server fits vmapped over the seed axis, and the iterative
    baselines (``run_vanilla`` / ``run_fedcvt`` / ``run_fedbcd``) stack
    their whole-session scan carries on a leading seed axis and train as
    one ``vmap``-of-scan program. The communication ledger is produced
    host-side ONCE and asserted byte-identical across seeds (each result
    carries its own copy). Every per-seed PRNG stream matches the
    corresponding single-seed run's exactly, so ``run_seeds`` agrees with
    a Python loop of single-seed runs at atol 1e-5
    (tests/test_seed_batched.py pins it, along with the
    zero-fresh-compiles contract for seeds ≥ 2).

    Unregistered runners — or seed sets whose splits don't share one
    shape — loop per seed over the runner's cached sessions, with the
    same ledger byte-identity assertion.

    Args mirror the runners', one entry per seed: ``keys[s]`` /
    ``splits[s]`` / ``extractors[s]`` / ``ssl_cfgs[s]``; ``cfg`` and
    ``runner_kwargs`` are shared. Per-seed *state* kwargs (``clients``,
    ``server``, ``ledger``) are rejected: one object cannot serve S seeds
    (a shared ledger would accumulate every seed's events and a shared
    client/server stack would be trained S times over) — call the runner
    directly for stateful single-seed composition. Returns one
    ``VFLResult`` per seed.
    """
    num_seeds = len(keys)
    if not (len(splits) == len(extractors) == len(ssl_cfgs) == num_seeds):
        raise ValueError("run_seeds needs one split / extractor stack / "
                         "ssl-cfg list per seed")
    from repro.core import runners as runner_registry
    runner_registry.reject_stateful_kwargs(
        "run_seeds", runner_kwargs, runner_registry.resolve(runner))
    faults = runner_kwargs.pop("faults", None)   # per-seed list → C = 1 grid
    if faults is not None:
        runner_kwargs["faults"] = [list(faults)]
    return run_scenarios_seeds(runner, [list(keys)], [list(splits)],
                               [list(extractors)], [list(ssl_cfgs)], cfg,
                               **runner_kwargs)[0]
