"""Weak/strong augmentations for local SSL.

Image augs implement FixMatch's recipe in pure JAX (jit/vmap-safe):
  weak  α(x): random horizontal flip + random translation (crop-with-pad);
  strong A(x): weak + cutout + per-channel color jitter + noise
  (a RandAugment-class perturbation implemented with jax.lax ops).

Tabular augs implement the paper's FixMatch-tab exactly (Eq. 5-6):
  m_i ~ Bernoulli(r_m),  n_i ~ N(0, σ²)
  α(x) = m ⊗ x + (1-m) ⊗ x̄          (mask-to-feature-mean)
  A(x) = α(x) + n                     (plus Gaussian noise)
where x̄ is the per-feature mean over the party's local data.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


# ------------------------------------------------------------------ images --
def _rand_flip(key, x):
    flip = jax.random.bernoulli(key, 0.5, (x.shape[0],))
    return jnp.where(flip[:, None, None, None], x[:, :, ::-1, :], x)


def _rand_translate(key, x, max_shift: int = 4):
    """Random integer translation via jnp.roll + edge zeroing (crop-with-pad)."""
    n, h, w, c = x.shape
    kx, ky = jax.random.split(key)
    dx = jax.random.randint(kx, (n,), -max_shift, max_shift + 1)
    dy = jax.random.randint(ky, (n,), -max_shift, max_shift + 1)

    def shift_one(img, dyi, dxi):
        img = jnp.roll(img, (dyi, dxi), axis=(0, 1))
        rows = jnp.arange(h)
        cols = jnp.arange(w)
        row_ok = jnp.where(dyi >= 0, rows >= dyi, rows < h + dyi)
        col_ok = jnp.where(dxi >= 0, cols >= dxi, cols < w + dxi)
        mask = row_ok[:, None] & col_ok[None, :]
        return img * mask[:, :, None]

    return jax.vmap(shift_one)(x, dy, dx)


def _cutout(key, x, size: int = 8):
    n, h, w, c = x.shape
    ky, kx = jax.random.split(key)
    cy = jax.random.randint(ky, (n,), 0, h)
    cx = jax.random.randint(kx, (n,), 0, w)
    rows = jnp.arange(h)[None, :, None]
    cols = jnp.arange(w)[None, None, :]
    mask = ((jnp.abs(rows - cy[:, None, None]) > size // 2)
            | (jnp.abs(cols - cx[:, None, None]) > size // 2))
    return x * mask[..., None]


def weak_augment_image(key, x, max_shift: int = 4):
    k1, k2 = jax.random.split(key)
    return _rand_translate(k2, _rand_flip(k1, x), max_shift)


def strong_augment_image(key, x, max_shift: int = 4, cutout_size: int = 8,
                         jitter: float = 0.25, noise: float = 0.1):
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    y = weak_augment_image(k1, x, max_shift)
    y = _cutout(k2, y, cutout_size)
    # per-sample per-channel affine color jitter
    gain = 1.0 + jitter * jax.random.uniform(k3, (x.shape[0], 1, 1, x.shape[-1]), minval=-1, maxval=1)
    bias = jitter * jax.random.uniform(k4, (x.shape[0], 1, 1, x.shape[-1]), minval=-1, maxval=1)
    y = y * gain + bias
    y = y + noise * jax.random.normal(k5, y.shape)
    return y


# ----------------------------------------------------------------- tabular --
def tab_augment_pair(key, x, feature_mean, mask_ratio: float = 0.2, sigma: float = 0.1
                     ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """FixMatch-tab (Eq. 5-6). Returns (weak, strong) sharing the same mask m,
    exactly as the paper specifies ("we first sample a binary mask for both
    weak and strong augmentation")."""
    km, kn = jax.random.split(key)
    keep = jax.random.bernoulli(km, 1.0 - mask_ratio, x.shape)  # m_i=1 keeps x_i
    weak = jnp.where(keep, x, feature_mean)
    noise = sigma * jax.random.normal(kn, x.shape)
    strong = weak + noise
    return weak, strong


def weak_augment_tab(key, x, feature_mean, mask_ratio: float = 0.2):
    keep = jax.random.bernoulli(key, 1.0 - mask_ratio, x.shape)
    return jnp.where(keep, x, feature_mean)


# ------------------------------------------------------------------ tokens --
def token_augment_pair(key, x, mask_id: int = 0, mask_ratio: float = 0.15,
                       strong_ratio: float = 0.4):
    """FixMatch-tab generalized to token sequences (DESIGN.md §4): weak =
    Bernoulli(r_m) token masking; strong = heavier masking. x: (B, S) int."""
    kw, ks = jax.random.split(key)
    keep_w = jax.random.bernoulli(kw, 1.0 - mask_ratio, x.shape)
    keep_s = keep_w & jax.random.bernoulli(ks, 1.0 - strong_ratio, x.shape)
    weak = jnp.where(keep_w, x, mask_id)
    strong = jnp.where(keep_s, x, mask_id)
    return weak, strong


def weak_augment_tokens(key, x, mask_id: int = 0, mask_ratio: float = 0.15):
    keep = jax.random.bernoulli(key, 1.0 - mask_ratio, x.shape)
    return jnp.where(keep, x, mask_id)
