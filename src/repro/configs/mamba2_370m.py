"""Mamba2 370M [arXiv:2405.21060] — attention-free SSD (state-space duality)."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    num_layers=48,
    d_model=1024,
    num_heads=0,                  # attention-free
    num_kv_heads=0,
    d_ff=0,                       # mamba blocks subsume the FFN
    vocab_size=50280,
    rope_style="none",
    ssm=SSMConfig(d_state=128, head_dim=64, expand=2, conv_width=4),
    source="arXiv:2405.21060",
))
