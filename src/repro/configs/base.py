"""ArchConfig — one declarative description drives model build, sharding,
dry-run and smoke tests for every assigned architecture."""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Dict, Optional


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0          # total shared-expert ffn width
    capacity_factor: float = 1.25  # train/prefill dispatch capacity


@dataclass(frozen=True)
class SSMConfig:
    d_state: int
    head_dim: int = 64
    expand: int = 2
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int             # compressed kv latent (deepseek: 512)
    q_lora_rank: int = 0          # 0 → full-rank q
    rope_head_dim: int = 64       # decoupled rope dims per head
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int                # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None          # default d_model // num_heads
    activation: str = "swiglu"              # swiglu | geglu
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    rope_style: str = "rope"                # rope | mrope | none
    norm_eps: float = 1e-6
    tie_embeddings: bool = False
    attn_window: Optional[int] = None       # sliding-window size (None = full)
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    mla: Optional[MLAConfig] = None
    # hybrid (zamba2-style): one SHARED attention block applied every
    # ``hybrid_attn_every`` ssm blocks, reusing the same weights
    hybrid_attn_every: int = 0
    # encoder-decoder (seamless-style)
    encoder_layers: int = 0                  # >0 → enc-dec; num_layers = decoder
    # modality frontend stub: prefix of precomputed embeddings
    prefix_tokens: int = 0                   # patches/frames in train/prefill
    source: str = ""                         # citation
    shard_ssm_heads: bool = False            # §Perf B6 policy (SSM/hybrid)
    shard_attn_heads: bool = False           # §Perf A3 policy (blocked attn)
    # --- numeric policy -----------------------------------------------------
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    optimizer: str = "adam"                  # adam | sgdm (dry-run memory knob)
    remat: bool = True

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this config decode at 500k context?"""
        return (self.family in ("ssm", "hybrid")) or (self.attn_window is not None)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: 2 layers, d_model<=512, <=4 experts — same
        family and code paths, CPU-sized."""
        d_model = min(self.d_model, 256)
        heads = min(self.num_heads, 4) if self.num_heads else 0
        kv = min(self.num_kv_heads, heads) if heads else 0
        if heads and self.num_kv_heads == self.num_heads:
            kv = heads
        changes: Dict = dict(
            num_layers=2,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=max(kv, 1) if heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.head_dim else None,
        )
        if self.moe:
            changes["moe"] = replace(self.moe, num_experts=4,
                                     top_k=min(self.moe.top_k, 2),
                                     d_ff_expert=min(self.moe.d_ff_expert, 128),
                                     d_ff_shared=min(self.moe.d_ff_shared, 128))
        if self.ssm:
            changes["ssm"] = replace(self.ssm, d_state=min(self.ssm.d_state, 16),
                                     chunk=32)
        if self.mla:
            changes["mla"] = replace(self.mla, kv_lora_rank=64, rope_head_dim=16,
                                     nope_head_dim=32, v_head_dim=32)
        if self.encoder_layers:
            changes["encoder_layers"] = 2
        if self.hybrid_attn_every:
            changes["hybrid_attn_every"] = 2
        if self.prefix_tokens:
            changes["prefix_tokens"] = 8
        if self.attn_window:
            changes["attn_window"] = min(self.attn_window, 64)
        return replace(self, **changes)


# ------------------------------------------------------------------ shapes --
@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------- registry --
_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    from repro import configs  # ensure all config modules imported
    configs.load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ArchConfig]:
    from repro import configs
    configs.load_all()
    return dict(_REGISTRY)
