"""Granite-MoE 3B-a800m [hf:ibm-granite/granite-3.0-*-base family] —
40 experts, top-8 routing, GQA kv=8."""
from repro.configs.base import ArchConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,                     # per-expert width
    vocab_size=49155,
    activation="swiglu",
    moe=MoEConfig(num_experts=40, top_k=8, d_ff_expert=512),
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
))
