"""Assigned-architecture configs. Each module registers one ArchConfig."""
import importlib

from repro.configs.base import (
    ArchConfig,
    INPUT_SHAPES,
    InputShape,
    MLAConfig,
    MoEConfig,
    SSMConfig,
    all_configs,
    get_config,
    register,
)

_MODULES = [
    "gemma_7b",
    "phi4_mini_3_8b",
    "qwen1_5_32b",
    "qwen2_vl_72b",
    "zamba2_1_2b",
    "seamless_m4t_large_v2",
    "mamba2_370m",
    "llama3_405b",
    "granite_moe_3b_a800m",
    "deepseek_v2_236b",
]

_loaded = False


def load_all() -> None:
    global _loaded
    if _loaded:
        return
    _loaded = True
    for m in _MODULES:
        importlib.import_module(f"repro.configs.{m}")
