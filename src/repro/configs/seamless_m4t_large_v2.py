"""SeamlessM4T-large v2 [arXiv:2308.11596] — encoder-decoder, multimodal.

The mel-spectrogram + conformer feature extractor is a STUB per the brief:
input_specs() provides precomputed audio-frame embeddings consumed by the
transformer encoder; the text decoder cross-attends to the encoder output.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    num_layers=24,                # decoder layers
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    activation="gelu",
    rope_style="none",            # learned/sinusoidal positions in the original
    prefix_tokens=1024,           # audio-frame embeddings fed to the encoder
    source="arXiv:2308.11596",
))
