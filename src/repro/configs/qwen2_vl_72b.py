"""Qwen2-VL 72B [arXiv:2409.12191] — VLM backbone: M-RoPE, GQA kv=8.

The ViT/dynamic-resolution frontend is a STUB per the brief: input_specs()
provides precomputed patch embeddings (prefix_tokens, d_model) that the
backbone consumes with 3D M-RoPE position ids.
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-72b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    activation="swiglu",
    qkv_bias=True,
    rope_style="mrope",
    rope_theta=1000000.0,
    prefix_tokens=1024,            # patch-embedding prefix in train/prefill
    source="arXiv:2409.12191",
))
