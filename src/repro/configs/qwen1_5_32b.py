"""Qwen1.5 32B [hf:Qwen/Qwen1.5-0.5B family] — dense MHA with QKV bias."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="qwen1.5-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
))
