"""Llama-3 405B [arXiv:2407.21783] — dense GQA kv=8, 128k-class vocab."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128256,
    activation="swiglu",
    rope_theta=500000.0,
    source="arXiv:2407.21783",
))
