"""Phi-4-mini 3.8B [arXiv:2412.08905] — dense, RoPE, SwiGLU, GQA kv=8."""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="phi4-mini-3.8b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=200064,
    activation="swiglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2412.08905",
))
