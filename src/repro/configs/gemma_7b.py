"""Gemma 7B [arXiv:2403.08295] — dense, GeGLU, head_dim=256, MHA (kv=16).

(The 2B sibling uses MQA; the assigned 7B uses full multi-head, per the
model card.)
"""
from repro.configs.base import ArchConfig, register

CONFIG = register(ArchConfig(
    name="gemma-7b",
    family="dense",
    num_layers=28,
    d_model=3072,
    num_heads=16,
    num_kv_heads=16,
    d_ff=24576,
    vocab_size=256000,
    head_dim=256,
    activation="geglu",
    rope_theta=10000.0,
    tie_embeddings=True,
    source="arXiv:2403.08295",
))
