"""Zamba2 1.2B [arXiv:2411.15242] — hybrid: Mamba2 backbone + one SHARED
attention block (same weights) applied periodically."""
from repro.configs.base import ArchConfig, SSMConfig, register

CONFIG = register(ArchConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,                # mamba2 blocks
    d_model=2048,
    num_heads=32,                 # the shared attention block
    num_kv_heads=32,
    d_ff=8192,                    # shared block's MLP
    vocab_size=32000,
    activation="swiglu",
    ssm=SSMConfig(d_state=64, head_dim=64, expand=2, conv_width=4),
    hybrid_attn_every=6,          # shared attn after every 6 mamba blocks
    source="arXiv:2411.15242",
))
