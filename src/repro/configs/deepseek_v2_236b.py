"""DeepSeek-V2 236B [arXiv:2405.04434] — MLA (kv_lora=512) + fine-grained MoE
(160 routed top-6 + 2 shared experts); first layer dense."""
from repro.configs.base import ArchConfig, MLAConfig, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-236b",
    family="moe",
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,             # MLA: all heads share the compressed latent
    d_ff=12288,                   # the dense first layer's FFN width
    vocab_size=102400,
    activation="swiglu",
    moe=MoEConfig(num_experts=160, top_k=6, d_ff_expert=1536,
                  num_shared_experts=2, d_ff_shared=3072),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    source="arXiv:2405.04434",
))
