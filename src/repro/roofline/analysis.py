"""Three-term roofline from the dry-run artifacts (TPU v5e targets).

  compute    = flops_per_device / peak_flops
  memory     = hbm_traffic_per_device / hbm_bw
  collective = collective_bytes_per_device / ici_bw

All inputs are per-device (the analyzed HLO is the partitioned module), so
no further division by chip count is needed. MODEL_FLOPS (6·N·D useful
flops) is computed analytically per config for the usefulness ratio.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

from repro.configs.base import ArchConfig, InputShape


@dataclass(frozen=True)
class Hardware:
    name: str = "tpu-v5e"
    peak_flops: float = 197e12        # bf16 / chip
    hbm_bw: float = 819e9             # B/s / chip
    ici_bw: float = 50e9              # B/s / link
    hbm_per_chip: float = 16 * 2**30


HW = Hardware()


def active_params(cfg: ArchConfig) -> float:
    """Parameters touched per token (dense count, or active-expert count for
    MoE) — the N in MODEL_FLOPS = 6·N·D."""
    d = cfg.d_model
    v = cfg.vocab_size
    total = v * d * (1 if cfg.tie_embeddings else 2)
    L = cfg.num_layers

    def attn_params():
        dh = cfg.resolved_head_dim
        if cfg.mla is not None:
            m = cfg.mla
            p = d * m.kv_lora_rank + d * m.rope_head_dim
            p += m.kv_lora_rank * cfg.num_heads * (m.nope_head_dim + m.v_head_dim)
            if m.q_lora_rank:
                p += d * m.q_lora_rank + m.q_lora_rank * cfg.num_heads * (m.nope_head_dim + m.rope_head_dim)
            else:
                p += d * cfg.num_heads * (m.nope_head_dim + m.rope_head_dim)
            p += cfg.num_heads * m.v_head_dim * d
            return p
        return d * cfg.num_heads * dh + 2 * d * cfg.num_kv_heads * dh \
            + cfg.num_heads * dh * d

    def ffn_params(width, glu=True):
        return (3 if glu else 2) * d * width

    glu = cfg.activation in ("swiglu", "geglu")
    if cfg.family in ("dense", "vlm"):
        total += L * (attn_params() + ffn_params(cfg.d_ff, glu))
    elif cfg.family == "moe":
        m = cfg.moe
        act_ffn = m.top_k * ffn_params(m.d_ff_expert, True) \
            + (ffn_params(m.d_ff_shared, True) if m.num_shared_experts else 0)
        n_moe = L - (1 if cfg.mla is not None else 0)
        total += n_moe * (attn_params() + act_ffn + d * m.num_experts)
        if cfg.mla is not None:
            total += attn_params() + ffn_params(cfg.d_ff, True)
    elif cfg.family == "ssm":
        di = cfg.ssm.expand * d
        nh = di // cfg.ssm.head_dim
        per = d * (2 * di + 2 * cfg.ssm.d_state + nh) + di * d
        total += L * per
    elif cfg.family == "hybrid":
        di = cfg.ssm.expand * d
        nh = di // cfg.ssm.head_dim
        per = d * (2 * di + 2 * cfg.ssm.d_state + nh) + di * d
        total += L * per
        n_super = L // cfg.hybrid_attn_every
        total += n_super * (attn_params() + ffn_params(cfg.d_ff, glu)) / n_super  # shared weights counted once
        # but FLOPs-wise the shared block runs n_super times; handled in model_flops
    elif cfg.family == "audio":
        total += cfg.encoder_layers * (attn_params() + ffn_params(cfg.d_ff, glu))
        total += L * (2 * attn_params() + ffn_params(cfg.d_ff, glu))
    return float(total)


def model_flops(cfg: ArchConfig, shape: InputShape) -> float:
    """6·N_active·D (training) or 2·N_active·D (inference) useful flops,
    D = tokens processed by this step."""
    n = active_params(cfg)
    if cfg.family == "hybrid":
        # shared attention block executes n_super times per forward
        d = cfg.d_model
        dh = cfg.resolved_head_dim
        glu = 3 if cfg.activation in ("swiglu", "geglu") else 2
        shared = d * cfg.num_heads * dh * 2 + 2 * d * cfg.num_kv_heads * dh \
            + glu * d * cfg.d_ff
        n += shared * (cfg.num_layers // cfg.hybrid_attn_every - 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch          # decode: one token per seq


def roofline_terms(per_device: Dict[str, float], hw: Hardware = HW) -> Dict[str, float]:
    """per_device: {dot_flops, traffic_bytes, collective_bytes} → seconds."""
    compute = per_device.get("dot_flops", 0.0) / hw.peak_flops
    memory = per_device.get("traffic_bytes", 0.0) / hw.hbm_bw
    collective = per_device.get("collective_bytes", 0.0) / hw.ici_bw
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": collective}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    return terms
