"""Scan-aware cost extraction from post-SPMD optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE (verified in
EXPERIMENTS.md §Dry-run) — useless for scanned layer stacks. This module
re-derives the three roofline inputs directly from ``compiled.as_text()``:

* dot FLOPs        — every ``dot`` op: 2 · |output| · K (K = contracted size
                     from the lhs operand's shape and lhs_contracting_dims);
* HBM traffic      — per *top-level* op in each executed computation:
                     Σ operand sizes + output size. Ops inside fused
                     computations are not separate kernels and are excluded
                     (their traffic is the fusion node's operands/outputs);
* collective bytes — output sizes of all-gather / all-reduce /
                     reduce-scatter / all-to-all / collective-permute ops.

All quantities are multiplied through the call graph: while bodies by their
``known_trip_count`` backend config, fusions/calls/conditionals by 1. The
HLO is the per-device partitioned module, so every number is PER DEVICE.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    """Bytes of a (possibly tuple) HLO type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_elems(type_str: str) -> int:
    m = _SHAPE_RE.search(type_str)
    if not m:
        return 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_dims(type_str: str) -> List[int]:
    m = _SHAPE_RE.search(type_str)
    if not m or not m.group(2):
        return []
    return [int(d) for d in m.group(2).split(",")]


@dataclass
class Op:
    name: str
    kind: str
    out_type: str
    operands: List[str]
    line: str


@dataclass
class Computation:
    name: str
    ops: List[Op] = field(default_factory=list)
    # name -> type string, includes parameters
    symbols: Dict[str, str] = field(default_factory=dict)


_COMP_HEADER = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*([\w\-]+)\((.*)")
_OPERAND = re.compile(r"%([\w.\-]+)")
_TRIP = re.compile(r"\"known_trip_count\":\{\"n\":\"(\d+)\"\}")
_CALL_ATTR = re.compile(r"(?:condition|body|to_apply|calls)=%?([\w.\-]+)")


def parse_hlo(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        s = line.strip()
        if not s or s.startswith("//") or s.startswith("HloModule"):
            continue
        hm = _COMP_HEADER.match(s)
        if hm and s.endswith("{"):
            cur = Computation(hm.group(1))
            comps[cur.name] = cur
            # parse parameter declarations: name: type
            for pname, ptype in re.findall(r"([\w.\-]+):\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)", hm.group(2)):
                cur.symbols[pname] = ptype
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        om = _OP_LINE.match(s)
        if om:
            name, otype, kind, rest = om.groups()
            # operand names: up to the closing paren of the op call — take
            # all %refs before any attribute section; good enough because
            # attrs reference computations which we track separately.
            paren = rest.split("),")[0]
            operands = _OPERAND.findall(paren)
            cur.symbols[name] = otype
            cur.ops.append(Op(name, kind, otype, operands, s))
    return comps


@dataclass
class HloCost:
    dot_flops: float = 0.0
    traffic_bytes: float = 0.0
    collective_bytes: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, int] = field(default_factory=dict)
    dot_count: int = 0
    while_trip_counts: List[int] = field(default_factory=list)

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())

    def as_dict(self) -> dict:
        return {
            "dot_flops": self.dot_flops,
            "traffic_bytes": self.traffic_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "total_collective_bytes": self.total_collective_bytes,
            "dot_count": self.dot_count,
            "while_trip_counts": self.while_trip_counts,
        }


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = _shape_elems(op.out_type)
    # contracted size from lhs shape and lhs_contracting_dims
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
    if not m or not op.operands:
        return 2.0 * out_elems  # fallback: rank-0 contraction
    lhs_type = comp.symbols.get(op.operands[0])
    if lhs_type is None:
        return 2.0 * out_elems
    dims = _shape_dims(lhs_type)
    k = 1
    if m.group(1):
        for i in m.group(1).split(","):
            idx = int(i)
            if idx < len(dims):
                k *= dims[idx]
    # batch dims are shared between output and lhs — already in out_elems
    return 2.0 * out_elems * k


_SLICE_KINDS = ("dynamic-slice", "gather", "dynamic-update-slice", "slice")
_DUS_KINDS = ("dynamic-update-slice",)


def _marked_comps(comps: Dict[str, Computation], kinds) -> set:
    """Computations that (transitively through fusion calls) contain one of
    ``kinds`` — used to cap phantom traffic: a dynamic-slice of stacked scan
    params reads one layer, not the whole stack; a dynamic-update-slice
    writes one layer's slice into an aliased buffer."""
    direct = set()
    calls: Dict[str, List[str]] = {}
    for name, comp in comps.items():
        calls[name] = []
        for op in comp.ops:
            if op.kind in kinds:
                direct.add(name)
            for _, callee in re.findall(r"(calls|to_apply)=%?([\w.\-]+)", op.line):
                calls[name].append(callee)
    changed = True
    while changed:
        changed = False
        for name, callees in calls.items():
            if name not in direct and any(c in direct for c in callees):
                direct.add(name)
                changed = True
    return direct


def _sliceish_comps(comps: Dict[str, Computation]) -> set:
    return _marked_comps(comps, _SLICE_KINDS)


def analyze_hlo_text(text: str) -> HloCost:
    comps = parse_hlo(text)
    sliceish = _sliceish_comps(comps)
    dusish = _marked_comps(comps, _DUS_KINDS)
    cost = HloCost()
    cost.collective_bytes = {k: 0.0 for k in _COLLECTIVES}
    cost.collective_counts = {k: 0 for k in _COLLECTIVES}

    entry = None
    for name in comps:
        if name.startswith("main") or ".main" in name:
            entry = name
    if entry is None:  # fall back: the computation containing a while/most ops
        entry = max(comps, key=lambda n: len(comps[n].ops))

    # computations reached via fusion `calls=`/`to_apply` are NOT separate
    # kernels: their dots count (with the caller's multiplier) but their op
    # traffic does not.
    from collections import deque

    # (comp, multiplier, is_kernel_level)
    queue = deque([(entry, 1.0, True)])
    seen_mult: Dict[Tuple[str, bool], float] = {}
    while queue:
        cname, mult, kernel_level = queue.popleft()
        comp = comps.get(cname)
        if comp is None:
            continue
        key = (cname, kernel_level)
        seen_mult[key] = seen_mult.get(key, 0.0) + mult
        if seen_mult[key] - mult > 0:
            pass  # accumulate repeated call sites
        for op in comp.ops:
            base = op.kind
            if base == "dot":
                cost.dot_flops += mult * _dot_flops(op, comp)
                cost.dot_count += 1
            if any(base == c or base == c + "-start" for c in _COLLECTIVES):
                kind = base.replace("-start", "")
                b = _shape_bytes(op.out_type)
                cost.collective_bytes[kind] += mult * b
                cost.collective_counts[kind] += max(int(mult), 1)
            if kernel_level and base not in ("parameter", "constant",
                                             "get-tuple-element", "tuple",
                                             "bitcast", "while"):
                out_b = _shape_bytes(op.out_type)
                callee = None
                if base == "fusion":
                    m = re.search(r"calls=%?([\w.\-]+)", op.line)
                    callee = m.group(1) if m else None
                is_dus = base in _DUS_KINDS or (callee in dusish)
                is_slice = base in _SLICE_KINDS or (callee in sliceish)
                operand_bytes = []
                for o in op.operands:
                    t = comp.symbols.get(o)
                    if t:
                        operand_bytes.append(_shape_bytes(t))
                if is_dus and operand_bytes:
                    # aliased in-place update: the big buffer is neither fully
                    # read nor fully written — traffic ≈ the update slice(s)
                    opb = sum(operand_bytes) - max(operand_bytes)
                else:
                    # slicing kernels read ≤ their output; any other kernel
                    # reading ≫ it writes is touching a stacked staging
                    # buffer — cap at 4× output (allows genuine reductions)
                    cap = out_b if is_slice else 4 * out_b
                    opb = out_b + sum(min(b, cap) for b in operand_bytes)
                cost.traffic_bytes += mult * opb
            # call edges
            trip = None
            tm = _TRIP.search(op.line)
            if tm:
                trip = int(tm.group(1))
            for attr, callee in re.findall(r"(condition|body|to_apply|calls)=%?([\w.\-]+)", op.line):
                if attr == "body" and trip is not None:
                    cost.while_trip_counts.append(trip)
                    queue.append((callee, mult * trip, True))
                elif attr == "condition":
                    queue.append((callee, mult * (trip or 1), False))
                elif attr == "calls":          # fusion: dots yes, traffic no
                    queue.append((callee, mult, False))
                elif attr == "to_apply":       # reduce/map lambdas: negligible
                    continue
    return cost
