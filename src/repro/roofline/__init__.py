from repro.roofline.hlo_analysis import analyze_hlo_text, HloCost
from repro.roofline.analysis import roofline_terms, HW

__all__ = ["analyze_hlo_text", "HloCost", "roofline_terms", "HW"]
