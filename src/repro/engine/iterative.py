"""Iterative split-NN VFL sessions as ONE cached, jitted engine program.

The iterative baselines (vanilla SplitNN, the FedCVT-style cross-view
baseline) used to build an ad-hoc ``jax.jit`` step inside every
``run_*`` call: each invocation re-traced and re-compiled identical step
math, so scenario sweeps (``benchmarks/frontier.py`` runs every baseline
across an overlap sweep of one task) paid full compile time per scenario
point. This module is the iterative counterpart of ``engine.local_ssl``
(DESIGN.md §8):

* ``make_splitnn_step_fn`` — THE jointly-differentiated split-NN iteration
  (reps up, rep-gradients down; the communication is logged by the caller
  with the true tensor sizes);
* ``make_fedcvt_step_fn``  — the same iteration plus FedCVT-style
  cross-view training: unaligned batches whose missing-party reps are
  SDPA-estimated from the overlap batch join the loss when their
  pseudo-label confidence clears a threshold;
* ``make_fedbcd_step_fn`` — FedBCD-p [20]: one rep/partial-gradient
  exchange then Q parallel stale-gradient local updates per round;
* ``run_iterative_session`` — executes S iterations either as one jitted
  ``lax.scan`` over a precomputed minibatch schedule (``"scan"``, the
  fast path) or as a Python loop over the cached jitted step
  (``"python"``);
* ``run_iterative_session_seeds`` — the seed-axis fold (DESIGN.md §11):
  every array argument carries a leading seed axis and the whole
  multi-seed session runs as ONE ``vmap``-of-``lax.scan`` program. The
  single-seed ``run_iterative_session`` is its width-1 case, so one
  cached program serves every seed count (the cache key has no batch
  width — ``jax.jit`` re-specializes per stacked shape).

Compiled callables are cached in the engine-wide session cache
(``engine.sessions``, domain ``"iterative"``), keyed on the *semantic*
identity of the party models (apply-fn code object + closure cells — the
same guarantee ``local_ssl._apply_fns_match`` relies on) plus the
optimizer hyper-parameters, so repeated sessions (another seed, another
scenario point with equal minibatch shapes) re-use the compiled program
instead of re-tracing. ``session_cache_stats()`` exposes hit/miss
counters; tests pin the no-recompile contract with them.

Communication stays host-side: callers log per-round ledger events
around the jitted session, so both execution modes produce byte-identical
CommLedgers (the engine-refactor invariant of ``benchmarks/comm_cost``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.data.loader import epoch_batches
from repro.engine import sessions
from repro.models.extractors import Model


@dataclass(frozen=True)
class IterHParams:
    """Optimizer hyper-parameters of one iterative session (hashable — part
    of the session-cache key)."""
    client_lr: float = 0.01
    server_lr: float = 0.01
    momentum: float = 0.9
    fedcvt_threshold: float = 0.95


def resolve_mode(mode: str) -> str:
    """Map a requested engine mode onto an iterative execution path.

    ``"scan"`` (and the protocol layer's ``"vmap"``, its analogue for the
    one-shot engine) → the fused lax.scan session; ``"python"`` → per-step
    loop over the cached jitted step. ``"auto"`` honors the CI matrix knob
    ``REPRO_ENGINE_MODE`` and otherwise takes the fast path.
    """
    if mode == "python":
        return "python"
    if mode in ("scan", "vmap"):
        return "scan"
    if mode == "auto":
        env = os.environ.get("REPRO_ENGINE_MODE", "")
        return "python" if env == "python" else "scan"
    raise ValueError(f"unknown iterative engine mode {mode!r}")


# ----------------------------------------------------------- session cache
# The cache itself lives in ``engine.sessions`` (shared with the SSL and
# server-fit sessions); this module's historical API keeps its historical
# *scope* — stats over the iterative sessions only, so callers that
# interleave SSL/server fits between clear and assert see unchanged counts.
_model_key = sessions.model_key


def session_cache_stats() -> dict:
    return sessions.session_cache_stats("iterative")


def clear_session_cache() -> None:
    """Clears the whole engine-wide cache (all domains) — the conservative
    reading of the historical contract; per-domain stats reset with it."""
    sessions.clear_session_cache()


def _cached(key: tuple, builder: Callable[[], Callable]) -> Callable:
    return sessions.cached_session("iterative", key, builder)


# ------------------------------------------------------------ step factories
def make_splitnn_step_fn(extractors: Sequence[Model], classifier: Model,
                         hp: IterHParams):
    """One SplitNN iteration: joint value_and_grad over every party's
    extractor and the server classifier. Gradients are computed in one
    backward pass for efficiency, but the *communication* of the iteration
    is exactly reps-up + rep-grads-down (the caller logs it).

    Returns ``step(carry, xs, y, xs_u=None) -> (carry, loss)`` with
    ``carry = (client_params, server_params, opt_states, opt_state_s)``.
    """
    from repro.core.server import concat_reps   # deferred: core imports engine
    from repro.core.ssl import cross_entropy

    extractors = tuple(extractors)
    txs = tuple(optim.sgd(hp.client_lr, momentum=hp.momentum)
                for _ in extractors)
    tx_s = optim.sgd(hp.server_lr, momentum=hp.momentum)

    def step(carry, xs, y, xs_u=None):
        del xs_u
        cp, sp, oss, os_s = carry

        def loss_fn(cp_t, sp_):
            reps = [ext.apply(p.extractor, x)
                    for ext, p, x in zip(extractors, cp_t, xs)]
            logits = classifier.apply(sp_, concat_reps(reps))
            return jnp.mean(cross_entropy(logits, y))

        loss, (g_c, g_s) = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, sp)
        new_cp, new_os = [], []
        for p, g, tx, os_ in zip(cp, g_c, txs, oss):
            upd, os_ = tx.update(g, os_, p)
            new_cp.append(optim.apply_updates(p, upd))
            new_os.append(os_)
        upd_s, os_s = tx_s.update(g_s, os_s, sp)
        sp = optim.apply_updates(sp, upd_s)
        return (tuple(new_cp), sp, tuple(new_os), os_s), loss

    return step


def make_fedcvt_step_fn(extractors: Sequence[Model], classifier: Model,
                        hp: IterHParams):
    """SplitNN iteration + FedCVT-style cross-view expansion: each party's
    unaligned batch is completed with SDPA-estimated missing-party reps and
    joins the loss where the (stop-gradient) pseudo-label confidence clears
    ``hp.fedcvt_threshold``. Signature matches ``make_splitnn_step_fn`` with
    ``xs_u`` required."""
    from repro.core import estimator          # deferred: core imports engine
    from repro.core.server import concat_reps
    from repro.core.ssl import cross_entropy

    extractors = tuple(extractors)
    txs = tuple(optim.sgd(hp.client_lr, momentum=hp.momentum)
                for _ in extractors)
    tx_s = optim.sgd(hp.server_lr, momentum=hp.momentum)
    K = len(extractors)

    def step(carry, xs, y, xs_u):
        cp, sp, oss, os_s = carry

        def loss_fn(cp_t, sp_):
            reps_o = [ext.apply(p.extractor, x)
                      for ext, p, x in zip(extractors, cp_t, xs)]
            logits = classifier.apply(sp_, concat_reps(reps_o))
            loss = jnp.mean(cross_entropy(logits, y))
            for k_idx in range(K):
                h_u = extractors[k_idx].apply(cp_t[k_idx].extractor,
                                              xs_u[k_idx])
                parts = []
                for j in range(K):
                    if j == k_idx:
                        parts.append(h_u)
                    else:
                        parts.append(estimator.sdpa_transform(
                            h_u, reps_o[k_idx], reps_o[j]))
                logits_u = classifier.apply(sp_, concat_reps(parts))
                p_u = jax.nn.softmax(jax.lax.stop_gradient(logits_u), axis=-1)
                pseudo = jnp.argmax(p_u, axis=-1)
                mask = (jnp.max(p_u, axis=-1)
                        > hp.fedcvt_threshold).astype(jnp.float32)
                ce = cross_entropy(logits_u, pseudo)
                loss = loss + jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask),
                                                               1.0)
            return loss

        loss, (g_c, g_s) = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, sp)
        new_cp, new_os = [], []
        for p, g, tx, os_ in zip(cp, g_c, txs, oss):
            upd, os_ = tx.update(g, os_, p)
            new_cp.append(optim.apply_updates(p, upd))
            new_os.append(os_)
        upd_s, os_s = tx_s.update(g_s, os_s, sp)
        sp = optim.apply_updates(sp, upd_s)
        return (tuple(new_cp), sp, tuple(new_os), os_s), loss

    return step


def make_fedbcd_step_fn(extractors: Sequence[Model], classifier: Model,
                        hp: IterHParams, q: int):
    """One FedBCD-p communication round [20]: fresh reps up and partial
    gradients down ONCE, then ``q`` parallel local updates — clients on the
    stale rep-gradients (the ⟨stale ∂L/∂H, f_k(x;θ)⟩ surrogate), the server
    on the stale reps. Signature matches ``make_splitnn_step_fn``; the loss
    returned is the round-entry joint loss (before any local update)."""
    from repro.core.server import concat_reps   # deferred: core imports engine
    from repro.core.ssl import cross_entropy

    extractors = tuple(extractors)
    txs = tuple(optim.sgd(hp.client_lr, momentum=hp.momentum)
                for _ in extractors)
    tx_s = optim.sgd(hp.server_lr, momentum=hp.momentum)

    def step(carry, xs, y, xs_u=None):
        del xs_u
        cp, sp, oss, os_s = carry
        reps = [ext.apply(p.extractor, x)
                for ext, p, x in zip(extractors, cp, xs)]

        def rep_loss(rep_list, sp_):
            logits = classifier.apply(sp_, concat_reps(rep_list))
            return jnp.mean(cross_entropy(logits, y))

        loss, g_reps = jax.value_and_grad(rep_loss, argnums=0)(reps, sp)

        new_cp, new_os = [], []
        for ext, p, os_, tx, x, g in zip(extractors, cp, oss, txs, xs,
                                         g_reps):
            def q_body(_, c, ext=ext, tx=tx, x=x, g=g):
                p_, os__ = c

                def local_obj(pp):
                    return jnp.sum(jax.lax.stop_gradient(g)
                                   * ext.apply(pp.extractor, x))

                gq = jax.grad(local_obj)(p_)
                upd, os__ = tx.update(gq, os__, p_)
                return optim.apply_updates(p_, upd), os__

            p, os_ = jax.lax.fori_loop(0, q, q_body, (p, os_))
            new_cp.append(p)
            new_os.append(os_)

        def s_body(_, c):
            sp_, os_s_ = c
            gs = jax.grad(lambda spp: rep_loss(
                [jax.lax.stop_gradient(r) for r in reps], spp))(sp_)
            upd, os_s_ = tx_s.update(gs, os_s_, sp_)
            return optim.apply_updates(sp_, upd), os_s_

        sp, os_s = jax.lax.fori_loop(0, q, s_body, (sp, os_s))
        return (tuple(new_cp), sp, tuple(new_os), os_s), loss

    return step


# -------------------------------------------------------------- schedules
def build_iteration_schedule(seed: int, n: int, batch_size: int,
                             iterations: int) -> jnp.ndarray:
    """(S, bs) int32 minibatch indices: shuffled epochs, drop-remainder,
    truncated/cycled to exactly ``iterations`` rows — materialized up front
    so the scan path and the Python path consume identical batches."""
    bs = min(batch_size, n)
    if iterations <= 0:                      # a no-op session is valid
        return jnp.zeros((0, bs), jnp.int32)
    rows: List[np.ndarray] = []
    e = 0
    while len(rows) < iterations:
        for b in epoch_batches(n, bs, seed + e):
            rows.append(b)
            if len(rows) == iterations:
                break
        e += 1
    return jnp.asarray(np.stack(rows), jnp.int32)


def build_unaligned_schedule(seed: int, pool_sizes: Sequence[int],
                             batch_size: int, iterations: int
                             ) -> Tuple[jnp.ndarray, ...]:
    """Per-party (S, bs) uniform draws from each private pool (FedCVT's
    unaligned batches). An EMPTY pool (a full-overlap party) yields
    zero-width (S, 0) rows — the step's masked unaligned term then sums
    over nothing and contributes exactly 0, mirroring the SSL engine's
    ``n_unlabeled == 0`` guard (regression: the full-catalog smoke runs
    fedcvt on edge/full-overlap)."""
    rng = np.random.RandomState(seed)
    return tuple(
        jnp.zeros((iterations, 0), jnp.int32) if n_u == 0
        else jnp.asarray(rng.randint(0, n_u, size=(iterations, batch_size)),
                         jnp.int32)
        for n_u in pool_sizes)


# ---------------------------------------------------------------- sessions
def run_iterative_session_seeds(
    cache_key: tuple,
    make_step: Callable[[], Callable],
    carry,
    xs: Sequence[jnp.ndarray],
    y: jnp.ndarray,
    schedule: jnp.ndarray,
    mode: str = "auto",
    xs_u: Optional[Sequence[jnp.ndarray]] = None,
    u_schedules: Optional[Sequence[jnp.ndarray]] = None,
    mesh=None,
    active_steps: Optional[jnp.ndarray] = None,
):
    """The seed-axis fold (DESIGN.md §11): run every seed's whole session
    as one program.

    ``active_steps`` (optional, (S,) int32 — DESIGN.md §16) is the fault
    axis: seed ``s`` commits only its first ``active_steps[s]`` steps — a
    dropped party stalls the round loop there, so the carry freezes
    (params AND optimizer state). Every step still COMPUTES (losses keep
    shape (S, iters); frozen steps report the loss at the frozen carry),
    so the faulted session is the same fixed-shape program with the
    truncation point as data. ``None`` (fault-free) keeps the historical
    cache key and program byte-identical.

    Every array argument carries a leading seed axis S: ``carry`` leaves
    are stacked on axis 0, ``xs``/``xs_u`` are per-party tuples of
    ``(S, n, d)`` stacks, ``y`` is ``(S, n)``, and the schedules are
    ``(S, iters, bs)`` — per-seed randomness lives in the schedule
    *contents*, which travel as arguments, never in the compiled program.

    ``"scan"`` executes ONE cached ``jax.vmap``-of-``lax.scan`` program
    under the SAME session-cache key as the historical single-seed scan
    session (the key has no batch width, so folding seeds adds zero fresh
    session builds; ``jax.jit`` re-specializes the cached program per
    stacked shape). ``"python"`` loops seeds × steps over the cached
    jitted step — byte-for-byte the historical per-seed fallback.

    With a resolved ``mesh`` the ``"scan"`` path shards the seed axis over
    the device mesh (DESIGN.md §14): stacked arguments pad to a
    device-count multiple with copies of seed 0, the vmap-of-scan runs
    under ``shard_map``, and results are stripped back host-side. The
    cache key gains the mesh identity; ``"python"`` ignores the mesh.

    Returns ``(carry, losses)`` with the same stacking and ``losses`` of
    shape ``(S, iters)``.
    """
    from repro.engine import parallel        # sibling: mesh plumbing

    mode = resolve_mode(mode)
    mesh = parallel.resolve_mesh(mesh)
    xs = tuple(xs)
    num_seeds = schedule.shape[0]
    if schedule.shape[1] == 0:               # zero iterations: no-op session
        return carry, jnp.zeros((num_seeds, 0))
    has_u = xs_u is not None
    if has_u:
        xs_u = tuple(xs_u)
        u_schedules = tuple(u_schedules)

    if mode == "python":
        step = _cached(("step", has_u) + cache_key,
                       lambda: jax.jit(make_step()))
        act = (None if active_steps is None
               else np.asarray(active_steps, np.int64))
        out_carries, out_losses = [], []
        for s in range(num_seeds):
            c = jax.tree_util.tree_map(lambda a: a[s], carry)
            sched = np.asarray(schedule[s])
            u_scheds = ([np.asarray(us[s]) for us in u_schedules]
                        if has_u else None)
            losses = []
            for i in range(sched.shape[0]):
                xb = tuple(x[s][sched[i]] for x in xs)
                xub = (tuple(xu[s][us[i]] for xu, us in zip(xs_u, u_scheds))
                       if has_u else None)
                # a stalled step still computes (matching the scan path's
                # frozen-carry loss exactly) but never commits the carry
                new_c, loss = step(c, xb, y[s][sched[i]], xub)
                if act is None or i < act[s]:
                    c = new_c
                losses.append(loss)
            out_carries.append(c)
            out_losses.append(jnp.stack(losses))
        return (jax.tree_util.tree_map(lambda *a: jnp.stack(a), *out_carries),
                jnp.stack(out_losses))

    # "scan": the whole multi-seed session is one jitted program with a
    # donated stacked carry — vmap's batch axis IS the seed axis. Under a
    # mesh that axis pads to a device-count multiple and shards (§14).
    # A faulted session (active_steps given) is a distinct cached program
    # (the carry-select adds structure) — the FAULT-FREE key stays
    # byte-identical to the historical one, and the truncation points
    # themselves are arguments, so faulted sweeps of any mask re-serve it.
    pad = parallel.pad_width(num_seeds, mesh)
    mkey = (parallel.mesh_key(mesh),)
    faulted = active_steps is not None
    fkey = ("faulted",) if faulted else ()
    if faulted:
        active = parallel.pad_stacked(
            jnp.asarray(active_steps, jnp.int32), pad)
    if has_u:
        def build():
            step = make_step()

            if faulted:
                def session(carry, xs, y, schedule, xs_u, u_scheds, active):
                    def body(c, inp):
                        i, il, ius = inp
                        new_c, loss = step(
                            c, tuple(x[il] for x in xs), y[il],
                            tuple(xu[iu] for xu, iu in zip(xs_u, ius)))
                        # past the truncation point the carry freezes —
                        # computed, never committed (loss stays recorded)
                        new_c = jax.tree_util.tree_map(
                            lambda a, b: jnp.where(i < active, a, b),
                            new_c, c)
                        return new_c, loss

                    steps = jnp.arange(schedule.shape[0])
                    return jax.lax.scan(body, carry,
                                        (steps, schedule, u_scheds))
            else:
                def session(carry, xs, y, schedule, xs_u, u_scheds):
                    def body(c, inp):
                        il, ius = inp
                        return step(c, tuple(x[il] for x in xs), y[il],
                                    tuple(xu[iu] for xu, iu in zip(xs_u, ius)))

                    return jax.lax.scan(body, carry, (schedule, u_scheds))

            return parallel.shard_jit(jax.vmap(session), mesh)

        session = _cached(("scan", True) + fkey + cache_key + mkey, build)
        args = (parallel.pad_stacked(carry, pad),
                parallel.pad_stacked(xs, pad),
                parallel.pad_stacked(y, pad),
                parallel.pad_stacked(schedule, pad),
                parallel.pad_stacked(xs_u, pad),
                parallel.pad_stacked(u_schedules, pad))
        out, losses = session(*(args + (active,) if faulted else args))
        return parallel.strip_stacked(out, num_seeds), losses[:num_seeds]

    def build():
        step = make_step()

        if faulted:
            def session(carry, xs, y, schedule, active):
                def body(c, inp):
                    i, il = inp
                    new_c, loss = step(c, tuple(x[il] for x in xs),
                                       y[il], None)
                    new_c = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(i < active, a, b), new_c, c)
                    return new_c, loss

                steps = jnp.arange(schedule.shape[0])
                return jax.lax.scan(body, carry, (steps, schedule))
        else:
            def session(carry, xs, y, schedule):
                def body(c, il):
                    return step(c, tuple(x[il] for x in xs), y[il], None)

                return jax.lax.scan(body, carry, schedule)

        return parallel.shard_jit(jax.vmap(session), mesh)

    session = _cached(("scan", False) + fkey + cache_key + mkey, build)
    args = (parallel.pad_stacked(carry, pad), parallel.pad_stacked(xs, pad),
            parallel.pad_stacked(y, pad), parallel.pad_stacked(schedule, pad))
    out, losses = session(*(args + (active,) if faulted else args))
    return parallel.strip_stacked(out, num_seeds), losses[:num_seeds]


def run_iterative_session(
    cache_key: tuple,
    make_step: Callable[[], Callable],
    carry,
    xs: Sequence[jnp.ndarray],
    y: jnp.ndarray,
    schedule: jnp.ndarray,
    mode: str = "auto",
    xs_u: Optional[Sequence[jnp.ndarray]] = None,
    u_schedules: Optional[Sequence[jnp.ndarray]] = None,
):
    """Run S = ``schedule.shape[0]`` iterations of ``make_step()``'s step —
    the width-1 case of :func:`run_iterative_session_seeds` (one cached
    program serves every seed count).

    ``cache_key`` identifies the step math (models + hyper-parameters);
    the compiled step/session is cached under it so later sessions with
    the same key (and minibatch shapes) never recompile. Training data
    travels as *arguments*, never in the cached closure, so one compiled
    session serves every seed/scenario point of equal shapes.

    Returns ``(carry, losses)`` with ``losses`` of shape (S,).
    """
    xs = tuple(xs)
    if schedule.shape[0] == 0:               # zero iterations: no-op session
        return carry, jnp.zeros((0,))
    has_u = xs_u is not None
    carry1 = jax.tree_util.tree_map(lambda a: jnp.asarray(a)[None], carry)
    out, losses = run_iterative_session_seeds(
        cache_key, make_step, carry1, tuple(x[None] for x in xs), y[None],
        schedule[None], mode,
        xs_u=(tuple(x[None] for x in xs_u) if has_u else None),
        u_schedules=(tuple(s[None] for s in u_schedules)
                     if has_u else None))
    return jax.tree_util.tree_map(lambda a: a[0], out), losses[0]


def session_cache_key(kind: str, extractors, classifier, hp: IterHParams,
                      q: Optional[int] = None) -> tuple:
    """THE cache key of one iterative step kind ("splitnn" | "fedcvt" |
    "fedbcd"): model semantics + hyper-parameters (+ Q for FedBCD). Both
    the single-seed sessions below and the seed fold
    (``engine.batched.*_sessions_seeds``) build their keys here, so the
    width-1 program and the fold can never drift onto separate cache
    entries."""
    key = (kind, tuple(_model_key(e) for e in extractors),
           _model_key(classifier), hp)
    return key if q is None else key + (int(q),)


def splitnn_session(extractors, classifier, hp: IterHParams, carry, xs, y,
                    schedule, mode: str = "auto"):
    """SplitNN session with the cache key derived from model semantics."""
    return run_iterative_session(
        session_cache_key("splitnn", extractors, classifier, hp),
        lambda: make_splitnn_step_fn(extractors, classifier, hp),
        carry, xs, y, schedule, mode)


def fedcvt_session(extractors, classifier, hp: IterHParams, carry, xs, y,
                   schedule, xs_u, u_schedules, mode: str = "auto"):
    """FedCVT-style session with the cache key derived from model semantics."""
    return run_iterative_session(
        session_cache_key("fedcvt", extractors, classifier, hp),
        lambda: make_fedcvt_step_fn(extractors, classifier, hp),
        carry, xs, y, schedule, mode, xs_u=xs_u, u_schedules=u_schedules)
