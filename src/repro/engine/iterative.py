"""Iterative split-NN VFL sessions as ONE cached, jitted engine program.

The iterative baselines (vanilla SplitNN, the FedCVT-style cross-view
baseline) used to build an ad-hoc ``jax.jit`` step inside every
``run_*`` call: each invocation re-traced and re-compiled identical step
math, so scenario sweeps (``benchmarks/frontier.py`` runs every baseline
across an overlap sweep of one task) paid full compile time per scenario
point. This module is the iterative counterpart of ``engine.local_ssl``
(DESIGN.md §8):

* ``make_splitnn_step_fn`` — THE jointly-differentiated split-NN iteration
  (reps up, rep-gradients down; the communication is logged by the caller
  with the true tensor sizes);
* ``make_fedcvt_step_fn``  — the same iteration plus FedCVT-style
  cross-view training: unaligned batches whose missing-party reps are
  SDPA-estimated from the overlap batch join the loss when their
  pseudo-label confidence clears a threshold;
* ``run_iterative_session`` — executes S iterations either as one jitted
  ``lax.scan`` over a precomputed minibatch schedule (``"scan"``, the
  fast path) or as a Python loop over the cached jitted step
  (``"python"``).

Compiled callables are cached in the engine-wide session cache
(``engine.sessions``, domain ``"iterative"``), keyed on the *semantic*
identity of the party models (apply-fn code object + closure cells — the
same guarantee ``local_ssl._apply_fns_match`` relies on) plus the
optimizer hyper-parameters, so repeated sessions (another seed, another
scenario point with equal minibatch shapes) re-use the compiled program
instead of re-tracing. ``session_cache_stats()`` exposes hit/miss
counters; tests pin the no-recompile contract with them.

Communication stays host-side: callers log per-round ledger events
around the jitted session, so both execution modes produce byte-identical
CommLedgers (the engine-refactor invariant of ``benchmarks/comm_cost``).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.data.loader import epoch_batches
from repro.engine import sessions
from repro.models.extractors import Model


@dataclass(frozen=True)
class IterHParams:
    """Optimizer hyper-parameters of one iterative session (hashable — part
    of the session-cache key)."""
    client_lr: float = 0.01
    server_lr: float = 0.01
    momentum: float = 0.9
    fedcvt_threshold: float = 0.95


def resolve_mode(mode: str) -> str:
    """Map a requested engine mode onto an iterative execution path.

    ``"scan"`` (and the protocol layer's ``"vmap"``, its analogue for the
    one-shot engine) → the fused lax.scan session; ``"python"`` → per-step
    loop over the cached jitted step. ``"auto"`` honors the CI matrix knob
    ``REPRO_ENGINE_MODE`` and otherwise takes the fast path.
    """
    if mode == "python":
        return "python"
    if mode in ("scan", "vmap"):
        return "scan"
    if mode == "auto":
        env = os.environ.get("REPRO_ENGINE_MODE", "")
        return "python" if env == "python" else "scan"
    raise ValueError(f"unknown iterative engine mode {mode!r}")


# ----------------------------------------------------------- session cache
# The cache itself lives in ``engine.sessions`` (shared with the SSL and
# server-fit sessions); this module's historical API keeps its historical
# *scope* — stats over the iterative sessions only, so callers that
# interleave SSL/server fits between clear and assert see unchanged counts.
_model_key = sessions.model_key


def session_cache_stats() -> dict:
    return sessions.session_cache_stats("iterative")


def clear_session_cache() -> None:
    """Clears the whole engine-wide cache (all domains) — the conservative
    reading of the historical contract; per-domain stats reset with it."""
    sessions.clear_session_cache()


def _cached(key: tuple, builder: Callable[[], Callable]) -> Callable:
    return sessions.cached_session("iterative", key, builder)


# ------------------------------------------------------------ step factories
def make_splitnn_step_fn(extractors: Sequence[Model], classifier: Model,
                         hp: IterHParams):
    """One SplitNN iteration: joint value_and_grad over every party's
    extractor and the server classifier. Gradients are computed in one
    backward pass for efficiency, but the *communication* of the iteration
    is exactly reps-up + rep-grads-down (the caller logs it).

    Returns ``step(carry, xs, y, xs_u=None) -> (carry, loss)`` with
    ``carry = (client_params, server_params, opt_states, opt_state_s)``.
    """
    from repro.core.server import concat_reps   # deferred: core imports engine
    from repro.core.ssl import cross_entropy

    extractors = tuple(extractors)
    txs = tuple(optim.sgd(hp.client_lr, momentum=hp.momentum)
                for _ in extractors)
    tx_s = optim.sgd(hp.server_lr, momentum=hp.momentum)

    def step(carry, xs, y, xs_u=None):
        del xs_u
        cp, sp, oss, os_s = carry

        def loss_fn(cp_t, sp_):
            reps = [ext.apply(p.extractor, x)
                    for ext, p, x in zip(extractors, cp_t, xs)]
            logits = classifier.apply(sp_, concat_reps(reps))
            return jnp.mean(cross_entropy(logits, y))

        loss, (g_c, g_s) = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, sp)
        new_cp, new_os = [], []
        for p, g, tx, os_ in zip(cp, g_c, txs, oss):
            upd, os_ = tx.update(g, os_, p)
            new_cp.append(optim.apply_updates(p, upd))
            new_os.append(os_)
        upd_s, os_s = tx_s.update(g_s, os_s, sp)
        sp = optim.apply_updates(sp, upd_s)
        return (tuple(new_cp), sp, tuple(new_os), os_s), loss

    return step


def make_fedcvt_step_fn(extractors: Sequence[Model], classifier: Model,
                        hp: IterHParams):
    """SplitNN iteration + FedCVT-style cross-view expansion: each party's
    unaligned batch is completed with SDPA-estimated missing-party reps and
    joins the loss where the (stop-gradient) pseudo-label confidence clears
    ``hp.fedcvt_threshold``. Signature matches ``make_splitnn_step_fn`` with
    ``xs_u`` required."""
    from repro.core import estimator          # deferred: core imports engine
    from repro.core.server import concat_reps
    from repro.core.ssl import cross_entropy

    extractors = tuple(extractors)
    txs = tuple(optim.sgd(hp.client_lr, momentum=hp.momentum)
                for _ in extractors)
    tx_s = optim.sgd(hp.server_lr, momentum=hp.momentum)
    K = len(extractors)

    def step(carry, xs, y, xs_u):
        cp, sp, oss, os_s = carry

        def loss_fn(cp_t, sp_):
            reps_o = [ext.apply(p.extractor, x)
                      for ext, p, x in zip(extractors, cp_t, xs)]
            logits = classifier.apply(sp_, concat_reps(reps_o))
            loss = jnp.mean(cross_entropy(logits, y))
            for k_idx in range(K):
                h_u = extractors[k_idx].apply(cp_t[k_idx].extractor,
                                              xs_u[k_idx])
                parts = []
                for j in range(K):
                    if j == k_idx:
                        parts.append(h_u)
                    else:
                        parts.append(estimator.sdpa_transform(
                            h_u, reps_o[k_idx], reps_o[j]))
                logits_u = classifier.apply(sp_, concat_reps(parts))
                p_u = jax.nn.softmax(jax.lax.stop_gradient(logits_u), axis=-1)
                pseudo = jnp.argmax(p_u, axis=-1)
                mask = (jnp.max(p_u, axis=-1)
                        > hp.fedcvt_threshold).astype(jnp.float32)
                ce = cross_entropy(logits_u, pseudo)
                loss = loss + jnp.sum(ce * mask) / jnp.maximum(jnp.sum(mask),
                                                               1.0)
            return loss

        loss, (g_c, g_s) = jax.value_and_grad(loss_fn, argnums=(0, 1))(cp, sp)
        new_cp, new_os = [], []
        for p, g, tx, os_ in zip(cp, g_c, txs, oss):
            upd, os_ = tx.update(g, os_, p)
            new_cp.append(optim.apply_updates(p, upd))
            new_os.append(os_)
        upd_s, os_s = tx_s.update(g_s, os_s, sp)
        sp = optim.apply_updates(sp, upd_s)
        return (tuple(new_cp), sp, tuple(new_os), os_s), loss

    return step


# -------------------------------------------------------------- schedules
def build_iteration_schedule(seed: int, n: int, batch_size: int,
                             iterations: int) -> jnp.ndarray:
    """(S, bs) int32 minibatch indices: shuffled epochs, drop-remainder,
    truncated/cycled to exactly ``iterations`` rows — materialized up front
    so the scan path and the Python path consume identical batches."""
    bs = min(batch_size, n)
    if iterations <= 0:                      # a no-op session is valid
        return jnp.zeros((0, bs), jnp.int32)
    rows: List[np.ndarray] = []
    e = 0
    while len(rows) < iterations:
        for b in epoch_batches(n, bs, seed + e):
            rows.append(b)
            if len(rows) == iterations:
                break
        e += 1
    return jnp.asarray(np.stack(rows), jnp.int32)


def build_unaligned_schedule(seed: int, pool_sizes: Sequence[int],
                             batch_size: int, iterations: int
                             ) -> Tuple[jnp.ndarray, ...]:
    """Per-party (S, bs) uniform draws from each private pool (FedCVT's
    unaligned batches)."""
    rng = np.random.RandomState(seed)
    return tuple(jnp.asarray(rng.randint(0, n_u, size=(iterations, batch_size)),
                             jnp.int32)
                 for n_u in pool_sizes)


# ---------------------------------------------------------------- sessions
def run_iterative_session(
    cache_key: tuple,
    make_step: Callable[[], Callable],
    carry,
    xs: Sequence[jnp.ndarray],
    y: jnp.ndarray,
    schedule: jnp.ndarray,
    mode: str = "auto",
    xs_u: Optional[Sequence[jnp.ndarray]] = None,
    u_schedules: Optional[Sequence[jnp.ndarray]] = None,
):
    """Run S = ``schedule.shape[0]`` iterations of ``make_step()``'s step.

    ``cache_key`` identifies the step math (models + hyper-parameters);
    the compiled step/session is cached under it so later sessions with
    the same key (and minibatch shapes) never recompile. Training data
    travels as *arguments*, never in the cached closure, so one compiled
    session serves every seed/scenario point of equal shapes.

    Returns ``(carry, losses)`` with ``losses`` of shape (S,).
    """
    mode = resolve_mode(mode)
    xs = tuple(xs)
    if schedule.shape[0] == 0:               # zero iterations: no-op session
        return carry, jnp.zeros((0,))
    has_u = xs_u is not None
    if has_u:
        xs_u = tuple(xs_u)
        u_schedules = tuple(u_schedules)

    if mode == "python":
        step = _cached(("step", has_u) + cache_key,
                       lambda: jax.jit(make_step()))
        sched = np.asarray(schedule)
        u_scheds = ([np.asarray(s) for s in u_schedules] if has_u else None)
        losses = []
        for i in range(sched.shape[0]):
            xb = tuple(x[sched[i]] for x in xs)
            xub = (tuple(xu[us[i]] for xu, us in zip(xs_u, u_scheds))
                   if has_u else None)
            carry, loss = step(carry, xb, y[sched[i]], xub)
            losses.append(loss)
        return carry, jnp.stack(losses) if losses else jnp.zeros((0,))

    # "scan": the whole session is one jitted program with donated carry.
    if has_u:
        def build():
            step = make_step()

            def session(carry, xs, y, schedule, xs_u, u_scheds):
                def body(c, inp):
                    il, ius = inp
                    return step(c, tuple(x[il] for x in xs), y[il],
                                tuple(xu[iu] for xu, iu in zip(xs_u, ius)))

                return jax.lax.scan(body, carry, (schedule, u_scheds))

            return jax.jit(session, donate_argnums=(0,))

        session = _cached(("scan", True) + cache_key, build)
        return session(carry, xs, y, schedule, xs_u, u_schedules)

    def build():
        step = make_step()

        def session(carry, xs, y, schedule):
            def body(c, il):
                return step(c, tuple(x[il] for x in xs), y[il], None)

            return jax.lax.scan(body, carry, schedule)

        return jax.jit(session, donate_argnums=(0,))

    session = _cached(("scan", False) + cache_key, build)
    return session(carry, xs, y, schedule)


def splitnn_session(extractors, classifier, hp: IterHParams, carry, xs, y,
                    schedule, mode: str = "auto"):
    """SplitNN session with the cache key derived from model semantics."""
    key = ("splitnn", tuple(_model_key(e) for e in extractors),
           _model_key(classifier), hp)
    return run_iterative_session(
        key, lambda: make_splitnn_step_fn(extractors, classifier, hp),
        carry, xs, y, schedule, mode)


def fedcvt_session(extractors, classifier, hp: IterHParams, carry, xs, y,
                   schedule, xs_u, u_schedules, mode: str = "auto"):
    """FedCVT-style session with the cache key derived from model semantics."""
    key = ("fedcvt", tuple(_model_key(e) for e in extractors),
           _model_key(classifier), hp)
    return run_iterative_session(
        key, lambda: make_fedcvt_step_fn(extractors, classifier, hp),
        carry, xs, y, schedule, mode, xs_u=xs_u, u_schedules=u_schedules)
