"""Device-parallel execution of the stacked engine axis (DESIGN.md §14).

Every fold so far — K parties (§2), S seeds (§10–11), C scenarios (§12) —
stacks entries on one ANONYMOUS leading batch axis and runs them as a
single vmapped program on ONE device. This module adds the last axis: a
1-D device mesh over which that stacked axis shards via ``shard_map``,
so an S·C·K-entry program runs W/D entries per device with near-linear
scaling and unchanged per-entry math.

Design rules (mirroring every previous fold):

* **The single-device path is the no-mesh case.** ``resolve_mesh``
  normalizes ``None`` / ``1`` / a 1-device mesh to ``None``; the cache-key
  component :func:`mesh_key` is then ``None`` and the compiled sessions are
  byte-for-byte the historical single-device programs.
* **Cache keys gain mesh identity, never width.** Session-cache keys
  extend with ``(axis_names, mesh_shape)`` — NOT the stacked batch width —
  so a warm cache at one batch width serves every other width on the same
  mesh (``jax.jit`` re-specializes per shape), and the first sharded run
  against a warm single-device cache takes exactly one mesh-keyed miss per
  session kind.
* **Pad host-side, strip host-side.** ``shard_map`` needs the leading axis
  divisible by the device count; :func:`pad_entries` / :func:`pad_stacked`
  append copies of entry 0 (real work whose outputs are discarded — entries
  are independent by construction, so dummies cannot perturb real ones) and
  the callers slice the first W results back out. Communication ledgers are
  logged host-side from the *real* entries only, so they stay byte-identical
  to the single-device fold.
* **Steering.** The mesh arrives via ``ProtocolConfig.mesh`` /
  ``IterativeConfig.mesh`` (``None`` | device count | ``jax.sharding.Mesh``)
  or the env knob ``REPRO_DEVICE_COUNT`` — the device-axis analogue of
  ``REPRO_ENGINE_MODE``. Results record ``diagnostics["device_fold"]``
  alongside ``seed_fold`` / ``scenario_fold``.
"""
from __future__ import annotations

import os
from typing import Any, List, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec

from repro.launch.mesh import BATCH_AXIS, make_batch_mesh


def resolve_mesh(mesh: Any = None) -> Optional[Mesh]:
    """Normalize a mesh request to ``Mesh`` or ``None`` (single-device).

    Accepts ``None`` (consult ``REPRO_DEVICE_COUNT``, else single-device),
    an ``int`` device count, or a ``jax.sharding.Mesh``. A width-1 request
    normalizes to ``None`` so the single-device path is literally the
    1-device mesh case under the same cache-key discipline. Idempotent —
    safe to call at every layer the mesh threads through.
    """
    if mesh is None:
        env = os.environ.get("REPRO_DEVICE_COUNT", "")
        if not env:
            return None
        mesh = int(env)
    if isinstance(mesh, int):
        if mesh <= 1:
            return None
        mesh = make_batch_mesh(mesh)
    if mesh.size <= 1:
        return None
    return mesh


def device_fold(mesh: Optional[Mesh]) -> int:
    """The device-axis fold width a resolved mesh implies (1 = no mesh)."""
    return 1 if mesh is None else int(mesh.size)


def mesh_key(mesh: Optional[Mesh]):
    """Hashable mesh identity for session-cache keys: axis names + shape,
    never the stacked batch width. ``None`` on the single-device path, so
    the historical single-device cache keys are unchanged."""
    if mesh is None:
        return None
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape))


def pad_width(n: int, mesh: Optional[Mesh]) -> int:
    """Entries to append so ``n`` divides the mesh's device count."""
    return 0 if mesh is None else (-n) % mesh.size


def pad_entries(entries: Sequence[Any], mesh: Optional[Mesh]) -> List[Any]:
    """Pad a flat host-side entry list to a device-count multiple by
    repeating entry 0; callers strip results back to ``len(entries)``."""
    entries = list(entries)
    return entries + [entries[0]] * pad_width(len(entries), mesh)


def pad_stacked(tree: Any, pad: int) -> Any:
    """Append ``pad`` copies of entry 0 along axis 0 of every leaf of an
    already-stacked pytree (the device-divisibility padding for arguments
    that arrive stacked rather than as host lists)."""
    if pad == 0:
        return tree
    return jax.tree_util.tree_map(
        lambda a: jnp.concatenate([a, jnp.repeat(a[:1], pad, axis=0)],
                                  axis=0), tree)


def strip_stacked(tree: Any, n: int) -> Any:
    """Inverse of :func:`pad_stacked`: keep the first ``n`` entries."""
    return jax.tree_util.tree_map(lambda a: a[:n], tree)


def shard_jit(fn, mesh: Optional[Mesh], donate_params: bool = True):
    """Compile a batched session over the stacked leading axis.

    ``mesh is None`` → the historical single-device ``jax.jit`` (stacked
    params donated). Otherwise the session is wrapped in ``shard_map`` with
    every input/output leaf sharded on its leading axis over ``BATCH_AXIS``
    — entries are independent, so per-device execution of W/D-entry slices
    is exactly the single-device program restricted to each slice. Donation
    is disabled on the sharded path: inputs arrive host-committed and are
    resharded onto the mesh, so their buffers are not reusable in place.
    """
    if mesh is None:
        return jax.jit(fn, donate_argnums=(0,) if donate_params else ())
    spec = PartitionSpec(BATCH_AXIS)
    return jax.jit(shard_map(fn, mesh=mesh, in_specs=spec, out_specs=spec,
                             check_rep=False))
