"""One ``use_kernels`` switch for the protocol's Pallas hot-spots.

The protocol used to carry two ad-hoc flags (``use_kmeans_kernel``,
``use_sdpa_kernel``); every kernel-served phase now routes through this
module so enabling the Pallas path is one decision (DESIGN.md §5). The
pure-jnp references remain the numerical oracles either way.

Since the kernel layer went fold-native (DESIGN.md §15), every entry point
here has a batched counterpart that takes the engine's stacked anonymous
batch axis (seeds × scenarios × parties upstream) and serves it as ONE
program — one cached vmapped jnp session or one batched Pallas grid launch,
selected by the same ``use_kernels`` switch. Session-cache keys (domains
``"kmeans"`` / ``"sdpa"``) carry the route + semantic hyper-parameters +
mesh identity, never the batch width, so the width-1 call IS the folded
call and a warm cache at one width serves every other.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core import clustering, estimator
from repro.engine import parallel, sessions


def pseudo_labels(key: jax.Array, partial_grads: jnp.ndarray, num_classes: int,
                  kmeans_iters: int = 25, use_kernels: bool = False,
                  restarts: int = 4) -> jnp.ndarray:
    """Step ③: k-means over partial gradients → Ŷ_o^k (Alg. 1 l.28).

    ``use_kernels=True`` serves the final full-size cluster assignment with
    the Pallas ``kmeans`` kernel.
    """
    return clustering.gradient_pseudo_labels(
        key, partial_grads, num_classes, kmeans_iters,
        use_kernel=use_kernels, restarts=restarts)


def pseudo_labels_batched(keys: jnp.ndarray, partial_grads: jnp.ndarray,
                          num_classes: int, kmeans_iters: int = 25,
                          use_kernels: bool = False, restarts: int = 4,
                          mesh=None) -> jnp.ndarray:
    """Step ③ for a stacked batch as ONE cached compiled program.

    keys (B, 2) raw PRNG keys, partial_grads (B, N, d) → (B, N) labels.
    ``use_kernels=True`` folds every entry's final assignment into ONE
    batched ``(B, N/BN)`` Pallas grid; otherwise the jnp single-entry
    program vmaps verbatim. A mesh shards the batch axis like any other
    stacked axis (DESIGN.md §14) — callers pad B to a device-count
    multiple (``parallel.pad_entries``/``pad_stacked``) and strip results.
    """
    mesh = parallel.resolve_mesh(mesh)
    route = "kernel" if use_kernels else "vmap"

    def build():
        def fold(ks, gs):
            return clustering.gradient_pseudo_labels_batched(
                ks, gs, num_classes, kmeans_iters, use_kernel=use_kernels,
                restarts=restarts)

        return parallel.shard_jit(fold, mesh, donate_params=False)

    fn = sessions.cached_session(
        "kmeans", (route, num_classes, kmeans_iters, restarts,
                   parallel.mesh_key(mesh)), build)
    return fn(keys, partial_grads)


def estimate_missing(h_u_k: jnp.ndarray, h_o_all: Sequence[jnp.ndarray],
                     k: int, use_kernels: bool = False) -> List[jnp.ndarray]:
    """Few-shot step ③': Eq. 10 SDPA estimation of the other parties'
    representations. ``use_kernels=True`` serves it with the Pallas
    flash-style blocked SDPA kernel.
    """
    return estimator.estimate_missing_parties(
        h_u_k, h_o_all, k, use_kernel=use_kernels)


def estimate_missing_batched(h_u_stack: jnp.ndarray,
                             h_o_stacks: Sequence[jnp.ndarray], k: int,
                             use_kernels: bool = False, mesh=None
                             ) -> List[jnp.ndarray]:
    """Few-shot ③' estimation over a stacked seed axis: ONE program per
    missing party instead of a (seed × party) Python loop.

    h_u_stack (S, N_u, d) — party k's unaligned reps per seed;
    h_o_stacks[j] (S, N_o, d_j) — party j's overlap reps per seed. Returns
    the K−1 estimates (S, N_u, d_j) for j ≠ k in party order. The kernel
    route launches one batched ``(S, N_u/BU, N_o/BO)`` grid per missing
    party; the jnp route vmaps the Eq. 10 oracle. Both run as ONE cached
    session (domain ``"sdpa"``) keyed on route + mesh identity only —
    ``jax.jit`` re-specializes per (S, shapes). Callers pad S for a mesh.
    """
    mesh = parallel.resolve_mesh(mesh)
    route = "kernel" if use_kernels else "vmap"

    def build():
        def fold(q, a, b):
            return estimator.sdpa_transform_batched(q, a, b,
                                                    use_kernel=use_kernels)

        return parallel.shard_jit(fold, mesh, donate_params=False)

    fn = sessions.cached_session("sdpa", (route, parallel.mesh_key(mesh)),
                                 build)
    return [fn(h_u_stack, h_o_stacks[k], h_o_j)
            for j, h_o_j in enumerate(h_o_stacks) if j != k]


def estimate_missing_fused(h_u_k: jnp.ndarray,
                           h_o_all: Sequence[jnp.ndarray], k: int,
                           use_kernels: bool = False) -> List[jnp.ndarray]:
    """Serving-path ③': all K−1 missing-party estimates for ONE query batch
    as a single batched grid launch (batch axis = the missing parties).

    When the kernel route is on and every other party's overlap reps share
    one shape, h_u/h_o^A broadcast across a (K−1)-wide batch and the K−1
    value matrices stack — one ``(K−1, N_u/BU, N_o/BO)`` launch replaces
    K−1 sequential ones. Ragged per-party rep dims (or the jnp route) fall
    back to :func:`estimate_missing`, whose kernel case is itself the
    width-1 batched grid.
    """
    others = [j for j in range(len(h_o_all)) if j != k]
    if (use_kernels and len(others) > 1
            and len({h_o_all[j].shape for j in others}) == 1):
        from repro.kernels.sdpa_estimator import ops as kops
        width = len(others)
        q = jnp.broadcast_to(h_u_k, (width,) + h_u_k.shape)
        a = jnp.broadcast_to(h_o_all[k], (width,) + h_o_all[k].shape)
        b = jnp.stack([h_o_all[j] for j in others])
        out = kops.sdpa_estimate_batched(q, a, b)
        return [out[i] for i in range(width)]
    return estimate_missing(h_u_k, h_o_all, k, use_kernels=use_kernels)
