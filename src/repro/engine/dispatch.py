"""One ``use_kernels`` switch for the protocol's Pallas hot-spots.

The protocol used to carry two ad-hoc flags (``use_kmeans_kernel``,
``use_sdpa_kernel``); every kernel-served phase now routes through this
module so enabling the Pallas path is one decision (DESIGN.md §5). The
pure-jnp references remain the numerical oracles either way.
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp

from repro.core import clustering, estimator


def pseudo_labels(key: jax.Array, partial_grads: jnp.ndarray, num_classes: int,
                  kmeans_iters: int = 25, use_kernels: bool = False,
                  restarts: int = 4) -> jnp.ndarray:
    """Step ③: k-means over partial gradients → Ŷ_o^k (Alg. 1 l.28).

    ``use_kernels=True`` serves the final full-size cluster assignment with
    the Pallas ``kmeans`` kernel.
    """
    return clustering.gradient_pseudo_labels(
        key, partial_grads, num_classes, kmeans_iters,
        use_kernel=use_kernels, restarts=restarts)


def estimate_missing(h_u_k: jnp.ndarray, h_o_all: Sequence[jnp.ndarray],
                     k: int, use_kernels: bool = False) -> List[jnp.ndarray]:
    """Few-shot step ③': Eq. 10 SDPA estimation of the other parties'
    representations. ``use_kernels=True`` serves it with the Pallas
    flash-style blocked SDPA kernel.
    """
    return estimator.estimate_missing_parties(
        h_u_k, h_o_all, k, use_kernel=use_kernels)
