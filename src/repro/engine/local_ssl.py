"""The one-shot VFL engine: ONE local-SSL training implementation.

This module is the single place the repo implements "client trains its
extractor+head by semi-supervised learning on pseudo-labels" (Alg. 1
l.28-34 / Alg. 2 l.11-19).  It is shared by

  * ``repro.core.protocol`` / ``repro.core.client`` — the host-scale
    protocol orchestrators (``local_ssl_train`` delegates here);
  * ``repro.launch.vfl_step`` — the multi-pod shard_map schedule, which
    closes the same ``make_ssl_step_fn`` step inside its ``lax.fori_loop``
    so the collective-count story is measured against the real step math.

Two execution paths, one set of step functions (DESIGN.md §2):

  fast path      ``train_clients_ssl(..., mode="vmap")`` — all parties'
                 params/data are stacked on a leading client axis and the
                 whole session runs as ONE jitted program:
                 ``vmap`` over clients × ``lax.scan`` over the step
                 schedule, with the stacked parameter buffers donated.
  fallback path  ``mode="python"`` — a per-client Python loop over the
                 same jitted step, for heterogeneous zoos (per-party
                 feature dims or extractor architectures that cannot
                 share one stacked shape).

Ragged per-party *sample counts* no longer force the fallback: a
``PartyTask`` may carry ``labeled_mask`` / ``unlabeled_mask`` validity
masks over data padded to a static capacity (DESIGN.md §9 — few-shot
phase ⑤' pads every party's gated labeled set to N_o + N_u), and masked
rows contribute exactly zero loss, so any combination of per-party gate
counts shares one stacked shape and the vmap fast path engages.

Both paths draw their minibatch schedule and per-step PRNG keys from
``build_schedule`` with identical per-party keys, so they are numerically
equivalent up to batched-matmul reassociation (tests/test_engine.py pins
this at atol 1e-5). Compiled sessions (the vmapped whole-session program
and the fallback's per-step jit alike) are cached in the engine-wide
session cache (``engine.sessions``, domain ``"ssl"``) keyed on semantic
model identity + SSL/optimizer hyper-parameters, so repeated sessions
across seeds and scenario sweeps never re-trace identical step math.

The stacked client axis is a plain batch axis: ``engine.batched`` folds
S seeds × K parties of a multi-seed sweep into one S·K-entry session of
the same cached program (DESIGN.md §10).
"""
from __future__ import annotations

import os
from dataclasses import dataclass
from typing import TYPE_CHECKING, Any, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.data.loader import epoch_batches
from repro.engine import sessions
from repro.models.extractors import Model

if TYPE_CHECKING:   # the engine is imported by repro.core.client — keep the
    from repro.core.ssl import SSLConfig   # runtime import edge one-way


class PartyParams(NamedTuple):
    """(extractor, head) parameter pytrees of one party's local model."""
    extractor: Any
    head: Any


@dataclass(frozen=True)
class SSLHParams:
    """Hyper-parameters of the local-SSL loop (paper defaults)."""
    epochs: int = 20
    batch_size: int = 32
    learning_rate: float = 0.01
    momentum: float = 0.9
    unlabeled_ratio: int = 2      # μ: unlabeled batch = μ × labeled batch
    grad_clip: float = 5.0


@dataclass(frozen=True)
class PartyTask:
    """One party's local-SSL problem: model, pseudo-labeled + private data.

    ``labeled_mask`` / ``unlabeled_mask`` (optional, per-row 0/1 validity)
    make the task *masked fixed-shape*: ``x_labeled`` is padded to a static
    capacity shared by every party and masked-out rows contribute zero
    loss. ``None`` means every row is valid (the one-shot phase-④ case).

    ``step_valid`` (optional, per-STEP 0/1 validity over the flattened
    epoch×batch schedule) is the fault axis (DESIGN.md §16): a 0 step
    computes but does not commit — params AND optimizer state freeze, so
    a straggler (trailing zeros), a dropped party (all zeros), or an
    APC-style representation-only party (all zeros) runs the SAME
    fixed-shape session as its healthy peers, mask as data. ``None``
    means every step commits (the fault-free case)."""
    extractor: Model
    head: Model
    params: PartyParams
    ssl_cfg: SSLConfig
    x_labeled: jnp.ndarray        # (N_l, …)  overlap (+ gated unaligned) rows
    y_pseudo: jnp.ndarray         # (N_l,)    cluster / server pseudo-labels
    x_unlabeled: jnp.ndarray      # (N_u, …)  party-private pool
    feature_mean: Optional[jnp.ndarray] = None   # x̄ for FixMatch-tab
    labeled_mask: Optional[jnp.ndarray] = None   # (N_l,) row validity
    unlabeled_mask: Optional[jnp.ndarray] = None  # (N_u,) row validity
    step_valid: Optional[jnp.ndarray] = None     # (S,) per-step commit mask


class Schedule(NamedTuple):
    """Precomputed minibatch/PRNG schedule for one party's SSL session."""
    idx_labeled: jnp.ndarray      # (S, bs_l) int32
    idx_unlabeled: jnp.ndarray    # (S, bs_u) int32
    step_keys: jnp.ndarray        # (S, 2)    per-step PRNG keys


def make_ssl_optimizer(hp: SSLHParams) -> optim.GradientTransformation:
    return optim.chain(optim.clip_by_global_norm(hp.grad_clip),
                       optim.sgd(hp.learning_rate, momentum=hp.momentum))


def make_ssl_step_fn(extractor: Model, head: Model, ssl_cfg: "SSLConfig",
                     tx: optim.GradientTransformation):
    """THE local-SSL step. Pure function of its arguments — jit it, scan it,
    vmap it, or close it inside a shard_map program; every caller in the
    repo gets its step from here.

    Returns ``step(params, opt_state, feature_mean, key, xb_l, yb_l, xb_u,
    mb_l=None, mb_u=None) -> (params, opt_state, metrics)`` where
    ``feature_mean`` may be None for modalities that don't use it
    (image/token) and ``mb_l`` / ``mb_u`` are the minibatch rows of a
    masked task's validity masks (None ⇒ all rows valid — the trailing
    defaults keep every positional caller, e.g. the multi-pod schedule's
    fori_loop, unchanged).
    """

    from repro.core.ssl import ssl_loss   # deferred: core.client imports us

    def logits_fn(params: PartyParams, x):
        return head.apply(params.head, extractor.apply(params.extractor, x))

    def step(params, opt_state, feature_mean, key, xb_l, yb_l, xb_u,
             mb_l=None, mb_u=None):
        def loss_fn(p):
            return ssl_loss(logits_fn, p, key, xb_l, yb_l, xb_u, ssl_cfg,
                            feature_mean, labeled_mask=mb_l,
                            unlabeled_mask=mb_u)

        (_, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, metrics

    return step


# ------------------------------------------------------------------ schedule
# Offset separating the unlabeled draw stream from the labeled shuffle
# stream. The labeled epochs seed RandomState(seed0 + e) and the unlabeled
# epochs RandomState(seed0 + 7919*e + _UNLABELED_STREAM): without the offset
# the two streams collide at e = 0 (both seed0), so the first epoch's
# labeled permutation and unlabeled index draws came from the SAME generator
# state. The offset is a prime far above any epoch count, so neither stream
# ever reuses the other's seed (7919*e + 104729 > e' for every e, e' < 10^4).
_UNLABELED_STREAM = 104729


def schedule_steps(n_labeled: int, hp: SSLHParams) -> int:
    """How many steps :func:`build_schedule` will flatten the epoch loop
    into — the length a ``PartyTask.step_valid`` mask must have. Mirrors
    the drop-remainder batching exactly (``epoch_batches``)."""
    bs_l = min(hp.batch_size, n_labeled)
    if bs_l == 0:
        return 0
    return hp.epochs * (n_labeled // bs_l)


def build_schedule(key: jax.Array, n_labeled: int, n_unlabeled: int,
                   hp: SSLHParams) -> Schedule:
    """Flatten the epoch×minibatch loop into one (S, …) step schedule.

    Labeled batches are shuffled epochs (drop-remainder); unlabeled batches
    are independent uniform draws (FixMatch's μ× larger batches) from a
    decorrelated stream (``_UNLABELED_STREAM``). Keys and indices are
    materialized up front so the scan path and the Python path consume
    bit-identical randomness. ``n_unlabeled == 0`` (a full-overlap party
    with an empty private pool) yields zero-width unlabeled batches; the
    masked loss path keeps them at exactly zero contribution.
    """
    bs_l = min(hp.batch_size, n_labeled)
    bs_u = min(hp.batch_size * hp.unlabeled_ratio, n_unlabeled)
    seed0 = int(jax.random.randint(key, (), 0, 2**31 - 1))
    idx_l: List[np.ndarray] = []
    idx_u: List[np.ndarray] = []
    for e in range(hp.epochs):
        u_rng = np.random.RandomState(seed0 + 7919 * e + _UNLABELED_STREAM)
        for batch in epoch_batches(n_labeled, bs_l, seed0 + e):
            idx_l.append(batch)
            idx_u.append(u_rng.randint(0, n_unlabeled, size=bs_u)
                         if n_unlabeled > 0 else np.zeros(0, np.int64))
    if not idx_l:                        # epochs == 0: an empty session
        return Schedule(
            idx_labeled=jnp.zeros((0, bs_l), jnp.int32),
            idx_unlabeled=jnp.zeros((0, bs_u), jnp.int32),
            step_keys=jnp.zeros((0, 2), jnp.uint32),
        )
    return Schedule(
        idx_labeled=jnp.asarray(np.stack(idx_l), jnp.int32),
        idx_unlabeled=jnp.asarray(np.stack(idx_u), jnp.int32),
        step_keys=jax.random.split(jax.random.fold_in(key, 1), len(idx_l)),
    )


# ------------------------------------------------------- fallback: Python loop
def _optimizer_key(hp: SSLHParams) -> tuple:
    """The hp fields the step math closes over (epochs/batch sizes only
    shape the schedule, which travels as arguments)."""
    return (hp.learning_rate, hp.momentum, hp.grad_clip)


def train_party_ssl(key: jax.Array, task: PartyTask, hp: SSLHParams
                    ) -> Tuple[PartyParams, dict]:
    """One party's SSL session as a Python loop over the cached jitted step."""
    tx = make_ssl_optimizer(hp)
    step = sessions.cached_session(
        "ssl",
        ("step", sessions.model_key(task.extractor),
         sessions.model_key(task.head), task.ssl_cfg, _optimizer_key(hp)),
        lambda: jax.jit(make_ssl_step_fn(task.extractor, task.head,
                                         task.ssl_cfg, tx)))
    sched = build_schedule(key, task.x_labeled.shape[0],
                           task.x_unlabeled.shape[0], hp)
    params, opt_state = task.params, tx.init(task.params)
    idx_l = np.asarray(sched.idx_labeled)
    idx_u = np.asarray(sched.idx_unlabeled)
    m_l, m_u = task.labeled_mask, task.unlabeled_mask
    sv = None if task.step_valid is None else np.asarray(task.step_valid)
    metrics: dict = {}
    for i in range(idx_l.shape[0]):
        # an invalid step still COMPUTES (so the recorded metrics match the
        # vmapped session's frozen-carry step exactly) but never commits:
        # params and optimizer state freeze together — no momentum coast
        new_params, new_opt, m = step(
            params, opt_state, task.feature_mean, sched.step_keys[i],
            task.x_labeled[idx_l[i]], task.y_pseudo[idx_l[i]],
            task.x_unlabeled[idx_u[i]],
            None if m_l is None else m_l[idx_l[i]],
            None if m_u is None else m_u[idx_u[i]])
        if sv is None or sv[i] > 0:
            params, opt_state = new_params, new_opt
        metrics = m
    return params, {k: float(v) for k, v in metrics.items()}


# ------------------------------------------------- fast path: vmap over clients
def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def _unstack(tree, k: int):
    return [jax.tree_util.tree_map(lambda a: a[i], tree) for i in range(k)]


def _apply_fns_match(a: Model, b: Model) -> bool:
    """True when two Models provably share forward semantics: the same
    function object, or the same factory code with equal captured closure
    values. The vmap fast path trains every party with party 0's apply fn,
    so shape equality alone is not enough — two architectures can share
    param shapes yet compute different functions."""
    fa, fb = a.apply, b.apply
    if fa is fb:
        return True
    if getattr(fa, "__code__", None) is not getattr(fb, "__code__", False):
        return False
    cells_a = [c.cell_contents for c in (fa.__closure__ or ())]
    cells_b = [c.cell_contents for c in (fb.__closure__ or ())]
    try:
        return bool(cells_a == cells_b)
    except Exception:
        return False


def tasks_are_homogeneous(tasks: Sequence[PartyTask]) -> bool:
    """True when every party's params/data/config share one stacked shape
    AND the extractor/head forward functions match — the precondition of
    the vmap fast path. Heterogeneous zoos (per-party feature dims or
    architectures) take the Python fallback. Ragged per-party *gate
    counts* are NOT heterogeneous: masked tasks pad to a shared static
    capacity (DESIGN.md §9), so their shapes — data and masks — match and
    the fast path engages at any combination of valid-row counts."""
    t0 = tasks[0]
    ref = jax.tree_util.tree_structure(t0.params)
    ref_shapes = [(l.shape, l.dtype) for l in jax.tree_util.tree_leaves(t0.params)]
    for t in tasks[1:]:
        if not (_apply_fns_match(t.extractor, t0.extractor)
                and _apply_fns_match(t.head, t0.head)):
            return False
        if jax.tree_util.tree_structure(t.params) != ref:
            return False
        if [(l.shape, l.dtype) for l in jax.tree_util.tree_leaves(t.params)] != ref_shapes:
            return False
        if (t.x_labeled.shape != t0.x_labeled.shape
                or t.x_unlabeled.shape != t0.x_unlabeled.shape
                or t.y_pseudo.shape != t0.y_pseudo.shape):
            return False
        if t.ssl_cfg != t0.ssl_cfg:
            return False
        for attr in ("feature_mean", "labeled_mask", "unlabeled_mask",
                     "step_valid"):
            a, a0 = getattr(t, attr), getattr(t0, attr)
            if (a is None) != (a0 is None):
                return False
            if a is not None and a.shape != a0.shape:
                return False
    return True


def parties_are_homogeneous(extractors: Sequence[Model],
                            ssl_cfgs: Sequence["SSLConfig"],
                            feature_shapes: Sequence[tuple]) -> bool:
    """Spec-level equivalent of :func:`tasks_are_homogeneous`: the vmap
    fast path's precondition evaluated *before* any ``PartyTask`` exists —
    from a scenario's extractor stack, SSL configs, and per-party aligned
    feature shapes. Equal data shapes alone are NOT sufficient (a model-zoo
    scenario can have equal dims but distinct forward functions, which
    legitimately takes the Python fallback); the apply-fn identity check is
    what the engine actually dispatches on."""
    e0 = extractors[0]
    if any(not _apply_fns_match(e, e0) for e in extractors[1:]):
        return False
    if any(e.rep_dim != e0.rep_dim for e in extractors[1:]):
        return False
    if any(c != ssl_cfgs[0] for c in ssl_cfgs[1:]):
        return False
    return len({tuple(s)[1:] for s in feature_shapes}) == 1


def train_parties_ssl_vmapped(keys: Sequence[jax.Array],
                              tasks: Sequence[PartyTask], hp: SSLHParams,
                              mesh=None
                              ) -> Tuple[List[PartyParams], List[dict]]:
    """All parties' SSL sessions as ONE jitted program: ``vmap`` over the
    stacked client axis, ``lax.scan`` over the flattened epoch×batch
    schedule, stacked parameter buffers donated to the compiled call.

    The compiled session is cached (``engine.sessions``, domain ``"ssl"``)
    on semantic model identity + SSLConfig + optimizer hyper-parameters;
    params, data, masks, and the schedule all travel as arguments, so a
    sweep's later seeds/scenario points of equal shapes re-serve it.

    With a resolved ``mesh`` the stacked client axis additionally shards
    across devices (DESIGN.md §14): the entry list pads to a device-count
    multiple with copies of entry 0, the session runs under ``shard_map``,
    and the padded tail is stripped host-side. The cache key gains the
    mesh identity (axis names + shape — never the batch width)."""
    from repro.engine import parallel        # sibling: mesh plumbing

    mesh = parallel.resolve_mesh(mesh)
    t0 = tasks[0]
    k = len(tasks)
    tx = make_ssl_optimizer(hp)

    tasks = parallel.pad_entries(tasks, mesh)
    keys = parallel.pad_entries(list(keys), mesh)
    scheds = [build_schedule(kk, t.x_labeled.shape[0], t.x_unlabeled.shape[0], hp)
              for kk, t in zip(keys, tasks)]
    if scheds[0].step_keys.shape[0] == 0:          # epochs == 0: no-op session
        return [t.params for t in tasks[:k]], [{} for _ in tasks[:k]]
    stacked_params = _stack([t.params for t in tasks])
    x_l = jnp.stack([t.x_labeled for t in tasks])
    y_l = jnp.stack([t.y_pseudo for t in tasks])
    x_u = jnp.stack([t.x_unlabeled for t in tasks])
    idx_l = jnp.stack([s.idx_labeled for s in scheds])
    idx_u = jnp.stack([s.idx_unlabeled for s in scheds])
    step_keys = jnp.stack([s.step_keys for s in scheds])
    fm = (None if t0.feature_mean is None
          else jnp.stack([t.feature_mean for t in tasks]))
    m_l = (None if t0.labeled_mask is None
           else jnp.stack([t.labeled_mask for t in tasks]))
    m_u = (None if t0.unlabeled_mask is None
           else jnp.stack([t.unlabeled_mask for t in tasks]))
    # the fault axis (DESIGN.md §16): per-step commit masks stack like any
    # other argument — presence shapes the program, CONTENTS never do, so
    # a sweep whose fault masks change re-serves the cached session
    sv = (None if t0.step_valid is None
          else jnp.stack([t.step_valid for t in tasks]))

    def build():
        step = make_ssl_step_fn(t0.extractor, t0.head, t0.ssl_cfg, tx)

        def one_party(params, feature_mean, x_lab, y_lab, x_unl,
                      mask_lab, mask_unl, i_l, i_u, keys_s, sv_steps):
            opt_state = tx.init(params)

            def body(carry, inp):
                p, o = carry
                if sv_steps is None:
                    il, iu, kk = inp
                    sv_t = None
                else:
                    il, iu, kk, sv_t = inp
                new_p, new_o, m = step(
                    p, o, feature_mean, kk,
                    x_lab[il], y_lab[il], x_unl[iu],
                    None if mask_lab is None else mask_lab[il],
                    None if mask_unl is None else mask_unl[iu])
                if sv_t is not None:
                    # invalid step: computed but not committed — params and
                    # optimizer state freeze together (no momentum coast)
                    new_p = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(sv_t > 0, a, b), new_p, p)
                    new_o = jax.tree_util.tree_map(
                        lambda a, b: jnp.where(sv_t > 0, a, b), new_o, o)
                return (new_p, new_o), m

            xs = ((i_l, i_u, keys_s) if sv_steps is None
                  else (i_l, i_u, keys_s, sv_steps))
            (params, _), ms = jax.lax.scan(body, (params, opt_state), xs)
            last = jax.tree_util.tree_map(lambda a: a[-1], ms)
            return params, last

        axes = tuple(None if arg is None else 0
                     for arg in (0, fm, 0, 0, 0, m_l, m_u, 0, 0, 0, sv))
        return parallel.shard_jit(jax.vmap(one_party, in_axes=axes), mesh)

    fn = sessions.cached_session(
        "ssl",
        ("vmap", sessions.model_key(t0.extractor), sessions.model_key(t0.head),
         t0.ssl_cfg, _optimizer_key(hp), fm is None, m_l is None, m_u is None,
         sv is None, parallel.mesh_key(mesh)),
        build)
    new_params, metrics = fn(stacked_params, fm, x_l, y_l, x_u, m_l, m_u,
                             idx_l, idx_u, step_keys, sv)
    params_list = _unstack(new_params, k)
    metrics_list = [{name: float(v[i]) for name, v in metrics.items()}
                    for i in range(k)]
    return params_list, metrics_list


# ---------------------------------------------------------------- dispatcher
def train_clients_ssl(key: jax.Array, tasks: Sequence[PartyTask],
                      hp: SSLHParams, mode: str = "auto", mesh=None
                      ) -> Tuple[List[PartyParams], List[dict], bool]:
    """Run every party's local-SSL session; returns (params, metrics, vmapped).

    mode: "auto" (vmap when ``tasks_are_homogeneous``), "vmap" (require the
    fast path; raises on heterogeneous tasks), or "python" (force the
    per-client fallback loop). Per-party keys are split identically for
    both paths, so "vmap" and "python" agree numerically to ~1e-5.
    ``mesh`` (optional, DESIGN.md §14) shards the fast path's stacked
    client axis across devices; the fallback loop ignores it.
    """
    if mode not in ("auto", "vmap", "python"):
        raise ValueError(f"unknown engine mode {mode!r}")
    keys = list(jax.random.split(key, len(tasks)))
    homogeneous = tasks_are_homogeneous(tasks)
    if mode == "auto":
        # CI matrix knob: REPRO_ENGINE_MODE=python forces the fallback loop;
        # =vmap prefers the fast path whenever the tasks allow it (without
        # the hard failure an explicit mode="vmap" argument carries), so one
        # env var exercises either engine path across the whole suite.
        env = os.environ.get("REPRO_ENGINE_MODE", "")
        if env == "python":
            mode = "python"
        elif env in ("vmap", "scan") and homogeneous:
            mode = "vmap"
    if mode == "vmap" and not homogeneous:
        raise ValueError("engine mode 'vmap' requires homogeneous party "
                         "tasks (same param/data shapes and SSLConfig); "
                         "use mode='auto' or 'python'")
    # explicit "vmap" always honors the request (even K=1); "auto" only
    # pays the stacked-program trace when there is >1 party to batch
    if mode == "vmap" or (mode == "auto" and homogeneous and len(tasks) > 1):
        params, metrics = train_parties_ssl_vmapped(keys, tasks, hp, mesh=mesh)
        return params, metrics, True
    params_list, metrics_list = [], []
    for kk, t in zip(keys, tasks):
        p, m = train_party_ssl(kk, t, hp)
        params_list.append(p)
        metrics_list.append(m)
    return params_list, metrics_list, False
