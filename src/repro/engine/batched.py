"""Seed-batched engine execution (DESIGN.md §10).

The vmapped SSL session's client axis is a plain batch axis: nothing in the
compiled program knows that entry ``i`` is "party i" rather than "party
i mod K of seed i // K". This module exploits that to make multi-seed
sweeps a *compiled* capability instead of a Python loop over seeds:

* :func:`train_clients_ssl_seeds` — S seeds × K parties fold into ONE
  stacked axis of S·K entries and train as one jitted program. The session
  cache (``engine.sessions``, domain ``"ssl"``) keys on semantic model
  identity + hyper-parameters, never on batch width, so seeds ≥ 2 add zero
  fresh session builds over a single-seed run (``jax.jit`` re-specializes
  the one cached session per stacked shape).
* :func:`pseudo_labels_seeds` — the step-③ gradient k-means over all
  S·K gradient matrices as one cached program (bit-identical to the
  per-call path; pinned in tests/test_seed_batched.py). Under
  ``use_kernels`` the fold HOLDS: every entry's final assignment runs in
  ONE batched ``(B, N/BN)`` Pallas grid (DESIGN.md §15).
* :func:`fewshot_probs_seeds` — few-shot ③' for one party over the
  stacked seed axis: Eq. 10 estimation (one batched SDPA grid per missing
  party on the kernel route) + the Eq. 8-9 gate as one vmapped cached
  session (domains ``"sdpa"`` / ``"fewshot_gate"``).
* :func:`fit_sessions_batched` — the server classifier fits
  (``core.server._fit``'s ``lax.scan`` session) vmapped over a leading
  batch axis: a multi-seed scenario point's K·S aux fits + S joint fits
  run as a handful of batched calls against one cached program.
* :func:`splitnn_sessions_seeds` / :func:`fedcvt_sessions_seeds` /
  :func:`fedbcd_sessions_seeds` — the ITERATIVE seed fold (DESIGN.md
  §11): the whole-session ``lax.scan`` carries of the SplitNN / FedCVT /
  FedBCD baselines (all parties' extractor params, the server head, both
  optimizer states) gain a leading seed axis and S seeds train as one
  ``vmap``-of-scan program, under the same session-cache keys as the
  single-seed sessions (zero fresh session builds for S ≥ 2).

Per-seed randomness is *reproduced*, not re-derived: every fold takes the
exact per-seed keys/schedules the single-seed path would have consumed, so
``core.protocol.run_seeds`` matches a Python loop of single-seed runs at
atol 1e-5 (bit-exact on CPU for the k-means and fit folds).

The batch axis is fully ANONYMOUS: "seed" never appears inside a fold, so
any flat list of shape-homogeneous entries may ride it. DESIGN.md §12
exploits exactly this — ``core.protocol.run_scenarios_seeds`` flattens C
grouped scenarios × S seeds scenario-major into these same entry points,
turning a whole frontier group into one stacked S·C·K program with zero
new engine code and zero new session-cache keys (the keys carry neither
batch width nor data shapes, so a C ≥ 2 fold against a warm C = 1 cache
compiles nothing fresh at the session level).

Heterogeneous shapes (per-party feature dims, ragged gradient dims) fall
back to per-entry execution — same numerics, no fold — and the fallback is
recorded in the caller's diagnostics (``kernel_fold`` 1) plus logged once,
never silent. The Pallas kernel path is NOT a fallback trigger anymore:
batch is a native leading grid dimension of both kernels (DESIGN.md §15).
"""
from __future__ import annotations

import logging
import os
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.engine import parallel, sessions
from repro.engine.local_ssl import (PartyParams, PartyTask, SSLHParams,
                                    tasks_are_homogeneous, train_clients_ssl,
                                    train_parties_ssl_vmapped)


def flatten_seed_tasks(tasks_per_seed: Sequence[Sequence[PartyTask]]
                       ) -> List[PartyTask]:
    """[[seed0 party0..K-1], [seed1 ...], …] → seed-major flat list."""
    return [t for seed_tasks in tasks_per_seed for t in seed_tasks]


def unflatten_seed_results(flat: Sequence[Any], num_seeds: int,
                           num_parties: int) -> List[List[Any]]:
    """Inverse of :func:`flatten_seed_tasks` for per-task results."""
    return [list(flat[s * num_parties:(s + 1) * num_parties])
            for s in range(num_seeds)]


# ------------------------------------------------------- SSL: the S·K fold
def train_clients_ssl_seeds(keys: Sequence[jax.Array],
                            tasks_per_seed: Sequence[Sequence[PartyTask]],
                            hp: SSLHParams, mode: str = "auto", mesh=None
                            ) -> Tuple[List[List[PartyParams]],
                                       List[List[dict]], List[str]]:
    """Every seed's every party's SSL session; returns per-seed
    ``(params, metrics)`` lists plus the engine path each seed trained on.

    ``S == 1`` delegates verbatim to :func:`train_clients_ssl` (the
    single-seed dispatcher — byte-for-byte the historical behavior).
    ``S > 1`` with a homogeneous S·K task set folds everything into one
    vmapped session; each seed's per-party keys are split exactly as the
    single-seed dispatcher splits them, so the fold and the loop consume
    identical schedules and PRNG streams.
    """
    num_seeds = len(tasks_per_seed)
    if num_seeds == 1:
        params, metrics, vmapped = train_clients_ssl(
            keys[0], tasks_per_seed[0], hp, mode=mode, mesh=mesh)
        return [params], [metrics], ["vmap" if vmapped else "python"]

    if mode not in ("auto", "vmap", "python"):
        raise ValueError(f"unknown engine mode {mode!r}")
    k = len(tasks_per_seed[0])
    flat = flatten_seed_tasks(tasks_per_seed)
    homogeneous = tasks_are_homogeneous(flat)
    eff = mode
    if mode == "auto":
        env = os.environ.get("REPRO_ENGINE_MODE", "")
        # multi-seed "auto" folds whenever the stacked shape exists — the
        # whole point of batching seeds — unless the CI matrix forces the
        # fallback loop (same knob the single-seed dispatcher honors)
        eff = "python" if env == "python" else ("vmap" if homogeneous
                                                else "python")
    if eff == "vmap":
        if not homogeneous:
            raise ValueError("engine mode 'vmap' requires homogeneous party "
                             "tasks across every seed of the fold; use "
                             "mode='auto' or 'python'")
        flat_keys = [kk for key in keys for kk in jax.random.split(key, k)]
        params, metrics = train_parties_ssl_vmapped(flat_keys, flat, hp,
                                                    mesh=mesh)
        return (unflatten_seed_results(params, num_seeds, k),
                unflatten_seed_results(metrics, num_seeds, k),
                ["vmap"] * num_seeds)
    out_p, out_m, paths = [], [], []
    for key, tasks in zip(keys, tasks_per_seed):
        params, metrics, vmapped = train_clients_ssl(key, tasks, hp,
                                                     mode=mode)
        out_p.append(params)
        out_m.append(metrics)
        paths.append("vmap" if vmapped else "python")
    return out_p, out_m, paths


# --------------------------------------------- k-means: fold over the batch
_log = logging.getLogger(__name__)
_ragged_fallback_logged = False


def _note_ragged_fallback(what: str) -> None:
    """Log the per-entry fallback ONCE per process — a degraded fold should
    be visible (diagnostics record it per row; this flags the first one)."""
    global _ragged_fallback_logged
    if not _ragged_fallback_logged:
        _ragged_fallback_logged = True
        _log.warning("%s: ragged entry shapes — per-entry fallback "
                     "(fold width 1); diagnostics record kernel_fold=1",
                     what)


def pseudo_labels_seeds(keys: Sequence[jax.Array],
                        partial_grads: Sequence[jnp.ndarray],
                        num_classes: int, kmeans_iters: int = 25,
                        use_kernels: bool = False, restarts: int = 4,
                        mesh=None, info: dict = None) -> List[jnp.ndarray]:
    """Step ③ for a flat (seed-major) batch of gradient matrices: one
    cached compiled program when every entry shares one shape —
    bit-identical per entry to the per-call path. ``use_kernels`` KEEPS the
    fold (batch is a native grid dimension of the Pallas kmeans kernel —
    one ``(B, N/BN)`` launch for the whole batch, DESIGN.md §15); only
    genuinely ragged gradient shapes fall back to per-entry execution,
    recorded in ``info`` (→ ``diagnostics["kernel_fold"]``) and logged
    once. ``info``, when given, receives ``{"fold": width}`` plus
    ``"fallback"`` with the reason on the degraded path."""
    from repro.engine.dispatch import (pseudo_labels,   # deferred: same package
                                       pseudo_labels_batched)
    n = len(partial_grads)
    if len({g.shape for g in partial_grads}) != 1:
        if info is not None:
            info["fold"] = 1
            info["fallback"] = "ragged gradient shapes"
        _note_ragged_fallback("pseudo_labels_seeds")
        return [pseudo_labels(k, g, num_classes, kmeans_iters,
                              use_kernels=use_kernels, restarts=restarts)
                for k, g in zip(keys, partial_grads)]
    mesh = parallel.resolve_mesh(mesh)
    out = pseudo_labels_batched(
        jnp.stack(parallel.pad_entries(keys, mesh)),
        jnp.stack(parallel.pad_entries(partial_grads, mesh)),
        num_classes, kmeans_iters=kmeans_iters, use_kernels=use_kernels,
        restarts=restarts, mesh=mesh)
    if info is not None:
        info["fold"] = n
    return [out[i] for i in range(n)]


# ------------------------------------------ few-shot ③': the seed-axis fold
def fewshot_probs_seeds(servers: Sequence[Any], k_idx: int,
                        h_u_stack: jnp.ndarray,
                        h_o_stacks: Sequence[jnp.ndarray],
                        threshold: float, use_kernels: bool = False,
                        mesh=None) -> jnp.ndarray:
    """Few-shot ③' for party ``k_idx`` over the stacked seed axis: Eq. 10
    estimation of every missing party + the Eq. 8-9 ``infer_prob`` gate,
    folded — no per-(seed, party) Python loop (DESIGN.md §15).

    ``h_u_stack`` (S, N_u, d_k) stacks the party's unaligned reps over
    seeds; ``h_o_stacks[j]`` (S, N_o, d_j) the per-party overlap reps.
    ``servers[s]`` supplies seed ``s``'s fitted aux/joint classifiers
    (asserted semantically equal across the fold, like every seed-batched
    model stack). Returns the (S, N_u) gating probabilities p̂.

    Two cached sessions serve any S — estimation (domain ``"sdpa"``, via
    ``dispatch.estimate_missing_batched``: ONE batched Pallas grid per
    missing party under ``use_kernels``, a vmapped jnp oracle otherwise)
    and gating (domain ``"fewshot_gate"``, keyed on the classifiers'
    semantic identity + threshold + mesh). The single-seed path is the
    width-1 case under the same keys.
    """
    from repro.core import estimator          # deferred: core imports engine
    from repro.engine import dispatch

    mesh = parallel.resolve_mesh(mesh)
    num_seeds = h_u_stack.shape[0]
    pad = parallel.pad_width(num_seeds, mesh)
    h_u_p = parallel.pad_stacked(h_u_stack, pad)
    h_o_p = [parallel.pad_stacked(h, pad) for h in h_o_stacks]
    ests = dispatch.estimate_missing_batched(h_u_p, h_o_p, k_idx,
                                             use_kernels=use_kernels,
                                             mesh=mesh)
    parts, ei = [], 0
    for j in range(len(h_o_stacks)):
        if j == k_idx:
            parts.append(h_u_p)
        else:
            parts.append(ests[ei])
            ei += 1
    full = jnp.concatenate(parts, axis=-1)    # concat_reps on the stacked axis

    aux_model = servers[0].aux_classifiers[k_idx]
    joint_model = servers[0].classifier
    amk = sessions.model_key(aux_model)
    jmk = sessions.model_key(joint_model)
    for srv in servers[1:]:
        if (sessions.model_key(srv.aux_classifiers[k_idx]) != amk
                or sessions.model_key(srv.classifier) != jmk):
            raise ValueError(
                "seed-batched few-shot gating requires semantically equal "
                "aux/joint classifiers across every seed of the fold")
    aux_stack = stack_carries(parallel.pad_entries(
        [srv.aux_params[k_idx] for srv in servers], mesh))
    joint_stack = stack_carries(parallel.pad_entries(
        [srv.params for srv in servers], mesh))

    def build():
        def one(h_u, full_rep, aux_p, joint_p):
            return estimator.infer_prob(
                lambda h: aux_model.apply(aux_p, h),
                lambda h: joint_model.apply(joint_p, h),
                h_u, full_rep, threshold)

        return parallel.shard_jit(jax.vmap(one), mesh, donate_params=False)

    fn = sessions.cached_session(
        "fewshot_gate", (amk, jmk, float(threshold),
                         parallel.mesh_key(mesh)), build)
    probs = fn(h_u_p, full, aux_stack, joint_stack)
    return probs[:num_seeds]


# ------------------------------------------- iterative baselines: seed fold
def stack_carries(carries: Sequence[Any]):
    """Per-seed session carries → one carry whose leaves have a leading
    seed axis (the inverse of :func:`unstack_carries`)."""
    return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *carries)


def unstack_carries(carry, num_seeds: int) -> List[Any]:
    """Split a stacked carry back into per-seed carries."""
    return [jax.tree_util.tree_map(lambda a: a[s], carry)
            for s in range(num_seeds)]


def _stack_party_data(per_seed: Sequence[Sequence[jnp.ndarray]]
                      ) -> Tuple[jnp.ndarray, ...]:
    """[[seed0 party0..K-1], …] → per-party tuple of (S, n, d) stacks.

    Parties may have heterogeneous feature dims — each party stacks only
    across seeds, where one scenario point's shapes agree by construction."""
    num_parties = len(per_seed[0])
    return tuple(jnp.stack([seed_xs[k] for seed_xs in per_seed])
                 for k in range(num_parties))


def _assert_seed_models_equal(extractors_per_seed, classifiers) -> None:
    ek0 = tuple(sessions.model_key(e) for e in extractors_per_seed[0])
    ck0 = sessions.model_key(classifiers[0])
    for exts, clf in zip(extractors_per_seed[1:], classifiers[1:]):
        if (tuple(sessions.model_key(e) for e in exts) != ek0
                or sessions.model_key(clf) != ck0):
            raise ValueError(
                "seed-batched iterative sessions require semantically equal "
                "party extractors and server classifier across every seed "
                "of the fold")


def splitnn_sessions_seeds(extractors_per_seed, classifiers,
                           hp, carries: Sequence[Any],
                           xs_per_seed, ys, schedules,
                           mode: str = "auto", mesh=None,
                           active_steps=None):
    """S seeds of one SplitNN session as ONE folded program.

    ``extractors_per_seed[s]`` / ``classifiers[s]`` are each seed's models
    (asserted semantically equal — one compiled step serves the fold);
    ``carries[s]`` the per-seed session carry; ``xs_per_seed[s]`` /
    ``ys[s]`` / ``schedules[s]`` the per-seed data and minibatch schedule.
    ``active_steps`` (optional, (S,) — DESIGN.md §16) truncates each
    seed's committed steps at a fault point, carry frozen past it.
    Returns ``(per-seed carries, (S, iters) losses)``.
    """
    from repro.engine import iterative        # deferred: sibling module

    _assert_seed_models_equal(extractors_per_seed, classifiers)
    exts, clf = extractors_per_seed[0], classifiers[0]
    carry, losses = iterative.run_iterative_session_seeds(
        iterative.session_cache_key("splitnn", exts, clf, hp),
        lambda: iterative.make_splitnn_step_fn(exts, clf, hp),
        stack_carries(carries), _stack_party_data(xs_per_seed),
        jnp.stack(list(ys)), jnp.stack(list(schedules)), mode, mesh=mesh,
        active_steps=active_steps)
    return unstack_carries(carry, len(carries)), losses


def fedcvt_sessions_seeds(extractors_per_seed, classifiers, hp,
                          carries: Sequence[Any], xs_per_seed, ys,
                          schedules, xs_u_per_seed, u_schedules,
                          mode: str = "auto", mesh=None,
                          active_steps=None):
    """S seeds of one FedCVT-style session as ONE folded program; the
    per-party unaligned pools and their draw schedules stack on the same
    seed axis. ``active_steps`` as in :func:`splitnn_sessions_seeds`.
    Returns ``(per-seed carries, (S, iters) losses)``."""
    from repro.engine import iterative        # deferred: sibling module

    _assert_seed_models_equal(extractors_per_seed, classifiers)
    exts, clf = extractors_per_seed[0], classifiers[0]
    num_parties = len(u_schedules[0])
    carry, losses = iterative.run_iterative_session_seeds(
        iterative.session_cache_key("fedcvt", exts, clf, hp),
        lambda: iterative.make_fedcvt_step_fn(exts, clf, hp),
        stack_carries(carries), _stack_party_data(xs_per_seed),
        jnp.stack(list(ys)), jnp.stack(list(schedules)), mode,
        xs_u=_stack_party_data(xs_u_per_seed),
        u_schedules=tuple(jnp.stack([us[k] for us in u_schedules])
                          for k in range(num_parties)), mesh=mesh,
        active_steps=active_steps)
    return unstack_carries(carry, len(carries)), losses


def fedbcd_sessions_seeds(extractors_per_seed, classifiers, hp, q: int,
                          carries: Sequence[Any], xs_per_seed, ys,
                          schedules, mode: str = "auto", mesh=None,
                          active_steps=None):
    """S seeds of one FedBCD-p session (Q local updates per round) as ONE
    folded program. ``active_steps`` as in :func:`splitnn_sessions_seeds`
    (units: communication ROUNDS). Returns ``(per-seed carries,
    (S, rounds) losses)``."""
    from repro.engine import iterative        # deferred: sibling module

    _assert_seed_models_equal(extractors_per_seed, classifiers)
    exts, clf = extractors_per_seed[0], classifiers[0]
    carry, losses = iterative.run_iterative_session_seeds(
        iterative.session_cache_key("fedbcd", exts, clf, hp, q),
        lambda: iterative.make_fedbcd_step_fn(exts, clf, hp, q),
        stack_carries(carries), _stack_party_data(xs_per_seed),
        jnp.stack(list(ys)), jnp.stack(list(schedules)), mode, mesh=mesh,
        active_steps=active_steps)
    return unstack_carries(carry, len(carries)), losses


# --------------------------------------------- server fits: vmapped sessions
def fit_sessions_batched(model, lr: float, params_list: Sequence[Any],
                         xs: Sequence[jnp.ndarray], ys: Sequence[jnp.ndarray],
                         schedules: Sequence[jnp.ndarray],
                         mesh=None) -> List[Any]:
    """A batch of server classifier fits as ONE cached vmapped ``lax.scan``
    session (domain ``"server_fit"``, keyed next to the plain session).

    Every entry must share the (x, y, schedule) shapes — true by
    construction for one scenario point's seeds, whose schedules differ
    only in *contents* (they travel as arguments). Entries may belong to
    different seeds or different aux-classifier parties alike: the batch
    axis is anonymous, exactly like the SSL fold's."""
    from repro.core.server import _fit_session        # deferred: core imports engine

    mesh = parallel.resolve_mesh(mesh)
    n = len(params_list)
    fitv = sessions.cached_session(
        "server_fit", ("vmap", sessions.model_key(model), float(lr),
                       parallel.mesh_key(mesh)),
        lambda: parallel.shard_jit(jax.vmap(_fit_session(model, lr)), mesh))
    stacked = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *parallel.pad_entries(params_list, mesh))
    out = fitv(stacked, jnp.stack(parallel.pad_entries(xs, mesh)),
               jnp.stack(parallel.pad_entries(ys, mesh)),
               jnp.stack(parallel.pad_entries(schedules, mesh)))
    return [jax.tree_util.tree_map(lambda a: a[i], out) for i in range(n)]
