"""Seed-batched engine execution (DESIGN.md §10).

The vmapped SSL session's client axis is a plain batch axis: nothing in the
compiled program knows that entry ``i`` is "party i" rather than "party
i mod K of seed i // K". This module exploits that to make multi-seed
sweeps a *compiled* capability instead of a Python loop over seeds:

* :func:`train_clients_ssl_seeds` — S seeds × K parties fold into ONE
  stacked axis of S·K entries and train as one jitted program. The session
  cache (``engine.sessions``, domain ``"ssl"``) keys on semantic model
  identity + hyper-parameters, never on batch width, so seeds ≥ 2 add zero
  fresh session builds over a single-seed run (``jax.jit`` re-specializes
  the one cached session per stacked shape).
* :func:`pseudo_labels_seeds` — the step-③ gradient k-means over all
  S·K gradient matrices as one cached ``vmap`` program (bit-identical to
  the per-call path; pinned in tests/test_seed_batched.py).
* :func:`fit_sessions_batched` — the server classifier fits
  (``core.server._fit``'s ``lax.scan`` session) vmapped over a leading
  batch axis: a multi-seed scenario point's K·S aux fits + S joint fits
  run as a handful of batched calls against one cached program.
* :func:`splitnn_sessions_seeds` / :func:`fedcvt_sessions_seeds` /
  :func:`fedbcd_sessions_seeds` — the ITERATIVE seed fold (DESIGN.md
  §11): the whole-session ``lax.scan`` carries of the SplitNN / FedCVT /
  FedBCD baselines (all parties' extractor params, the server head, both
  optimizer states) gain a leading seed axis and S seeds train as one
  ``vmap``-of-scan program, under the same session-cache keys as the
  single-seed sessions (zero fresh session builds for S ≥ 2).

Per-seed randomness is *reproduced*, not re-derived: every fold takes the
exact per-seed keys/schedules the single-seed path would have consumed, so
``core.protocol.run_seeds`` matches a Python loop of single-seed runs at
atol 1e-5 (bit-exact on CPU for the k-means and fit folds).

The batch axis is fully ANONYMOUS: "seed" never appears inside a fold, so
any flat list of shape-homogeneous entries may ride it. DESIGN.md §12
exploits exactly this — ``core.protocol.run_scenarios_seeds`` flattens C
grouped scenarios × S seeds scenario-major into these same entry points,
turning a whole frontier group into one stacked S·C·K program with zero
new engine code and zero new session-cache keys (the keys carry neither
batch width nor data shapes, so a C ≥ 2 fold against a warm C = 1 cache
compiles nothing fresh at the session level).

Heterogeneous shapes (per-party feature dims, ragged gradient dims) and
the Pallas kernel path (``pallas_call`` does not support interpret-mode
``vmap``) fall back to per-entry execution — same numerics, no fold.
"""
from __future__ import annotations

import os
from typing import Any, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.engine import parallel, sessions
from repro.engine.local_ssl import (PartyParams, PartyTask, SSLHParams,
                                    tasks_are_homogeneous, train_clients_ssl,
                                    train_parties_ssl_vmapped)


def flatten_seed_tasks(tasks_per_seed: Sequence[Sequence[PartyTask]]
                       ) -> List[PartyTask]:
    """[[seed0 party0..K-1], [seed1 ...], …] → seed-major flat list."""
    return [t for seed_tasks in tasks_per_seed for t in seed_tasks]


def unflatten_seed_results(flat: Sequence[Any], num_seeds: int,
                           num_parties: int) -> List[List[Any]]:
    """Inverse of :func:`flatten_seed_tasks` for per-task results."""
    return [list(flat[s * num_parties:(s + 1) * num_parties])
            for s in range(num_seeds)]


# ------------------------------------------------------- SSL: the S·K fold
def train_clients_ssl_seeds(keys: Sequence[jax.Array],
                            tasks_per_seed: Sequence[Sequence[PartyTask]],
                            hp: SSLHParams, mode: str = "auto", mesh=None
                            ) -> Tuple[List[List[PartyParams]],
                                       List[List[dict]], List[str]]:
    """Every seed's every party's SSL session; returns per-seed
    ``(params, metrics)`` lists plus the engine path each seed trained on.

    ``S == 1`` delegates verbatim to :func:`train_clients_ssl` (the
    single-seed dispatcher — byte-for-byte the historical behavior).
    ``S > 1`` with a homogeneous S·K task set folds everything into one
    vmapped session; each seed's per-party keys are split exactly as the
    single-seed dispatcher splits them, so the fold and the loop consume
    identical schedules and PRNG streams.
    """
    num_seeds = len(tasks_per_seed)
    if num_seeds == 1:
        params, metrics, vmapped = train_clients_ssl(
            keys[0], tasks_per_seed[0], hp, mode=mode, mesh=mesh)
        return [params], [metrics], ["vmap" if vmapped else "python"]

    if mode not in ("auto", "vmap", "python"):
        raise ValueError(f"unknown engine mode {mode!r}")
    k = len(tasks_per_seed[0])
    flat = flatten_seed_tasks(tasks_per_seed)
    homogeneous = tasks_are_homogeneous(flat)
    eff = mode
    if mode == "auto":
        env = os.environ.get("REPRO_ENGINE_MODE", "")
        # multi-seed "auto" folds whenever the stacked shape exists — the
        # whole point of batching seeds — unless the CI matrix forces the
        # fallback loop (same knob the single-seed dispatcher honors)
        eff = "python" if env == "python" else ("vmap" if homogeneous
                                                else "python")
    if eff == "vmap":
        if not homogeneous:
            raise ValueError("engine mode 'vmap' requires homogeneous party "
                             "tasks across every seed of the fold; use "
                             "mode='auto' or 'python'")
        flat_keys = [kk for key in keys for kk in jax.random.split(key, k)]
        params, metrics = train_parties_ssl_vmapped(flat_keys, flat, hp,
                                                    mesh=mesh)
        return (unflatten_seed_results(params, num_seeds, k),
                unflatten_seed_results(metrics, num_seeds, k),
                ["vmap"] * num_seeds)
    out_p, out_m, paths = [], [], []
    for key, tasks in zip(keys, tasks_per_seed):
        params, metrics, vmapped = train_clients_ssl(key, tasks, hp,
                                                     mode=mode)
        out_p.append(params)
        out_m.append(metrics)
        paths.append("vmap" if vmapped else "python")
    return out_p, out_m, paths


# ----------------------------------------------- k-means: vmap over the fold
def pseudo_labels_seeds(keys: Sequence[jax.Array],
                        partial_grads: Sequence[jnp.ndarray],
                        num_classes: int, kmeans_iters: int = 25,
                        use_kernels: bool = False, restarts: int = 4,
                        mesh=None) -> List[jnp.ndarray]:
    """Step ③ for a flat (seed-major) batch of gradient matrices: one
    cached ``vmap`` of the jittable k-means when every entry shares one
    shape — bit-identical per entry to the per-call path. The Pallas
    kernel path (``use_kernels``) and ragged gradient shapes run per entry
    (``pallas_call`` does not vmap in interpret mode)."""
    from repro.engine.dispatch import pseudo_labels   # deferred: same package
    if use_kernels or len({g.shape for g in partial_grads}) != 1:
        return [pseudo_labels(k, g, num_classes, kmeans_iters,
                              use_kernels=use_kernels)
                for k, g in zip(keys, partial_grads)]
    from repro.core import clustering                 # deferred: core imports engine

    mesh = parallel.resolve_mesh(mesh)
    n = len(partial_grads)

    def build():
        def one(key, grads):
            return clustering.gradient_pseudo_labels(
                key, grads, num_classes, kmeans_iters, use_kernel=False,
                restarts=restarts)

        return parallel.shard_jit(jax.vmap(one), mesh, donate_params=False)

    fn = sessions.cached_session(
        "kmeans", ("vmap", num_classes, kmeans_iters, restarts,
                   parallel.mesh_key(mesh)), build)
    out = fn(jnp.stack(parallel.pad_entries(keys, mesh)),
             jnp.stack(parallel.pad_entries(partial_grads, mesh)))
    return [out[i] for i in range(n)]


# ------------------------------------------- iterative baselines: seed fold
def stack_carries(carries: Sequence[Any]):
    """Per-seed session carries → one carry whose leaves have a leading
    seed axis (the inverse of :func:`unstack_carries`)."""
    return jax.tree_util.tree_map(lambda *a: jnp.stack(a), *carries)


def unstack_carries(carry, num_seeds: int) -> List[Any]:
    """Split a stacked carry back into per-seed carries."""
    return [jax.tree_util.tree_map(lambda a: a[s], carry)
            for s in range(num_seeds)]


def _stack_party_data(per_seed: Sequence[Sequence[jnp.ndarray]]
                      ) -> Tuple[jnp.ndarray, ...]:
    """[[seed0 party0..K-1], …] → per-party tuple of (S, n, d) stacks.

    Parties may have heterogeneous feature dims — each party stacks only
    across seeds, where one scenario point's shapes agree by construction."""
    num_parties = len(per_seed[0])
    return tuple(jnp.stack([seed_xs[k] for seed_xs in per_seed])
                 for k in range(num_parties))


def _assert_seed_models_equal(extractors_per_seed, classifiers) -> None:
    ek0 = tuple(sessions.model_key(e) for e in extractors_per_seed[0])
    ck0 = sessions.model_key(classifiers[0])
    for exts, clf in zip(extractors_per_seed[1:], classifiers[1:]):
        if (tuple(sessions.model_key(e) for e in exts) != ek0
                or sessions.model_key(clf) != ck0):
            raise ValueError(
                "seed-batched iterative sessions require semantically equal "
                "party extractors and server classifier across every seed "
                "of the fold")


def splitnn_sessions_seeds(extractors_per_seed, classifiers,
                           hp, carries: Sequence[Any],
                           xs_per_seed, ys, schedules,
                           mode: str = "auto", mesh=None):
    """S seeds of one SplitNN session as ONE folded program.

    ``extractors_per_seed[s]`` / ``classifiers[s]`` are each seed's models
    (asserted semantically equal — one compiled step serves the fold);
    ``carries[s]`` the per-seed session carry; ``xs_per_seed[s]`` /
    ``ys[s]`` / ``schedules[s]`` the per-seed data and minibatch schedule.
    Returns ``(per-seed carries, (S, iters) losses)``.
    """
    from repro.engine import iterative        # deferred: sibling module

    _assert_seed_models_equal(extractors_per_seed, classifiers)
    exts, clf = extractors_per_seed[0], classifiers[0]
    carry, losses = iterative.run_iterative_session_seeds(
        iterative.session_cache_key("splitnn", exts, clf, hp),
        lambda: iterative.make_splitnn_step_fn(exts, clf, hp),
        stack_carries(carries), _stack_party_data(xs_per_seed),
        jnp.stack(list(ys)), jnp.stack(list(schedules)), mode, mesh=mesh)
    return unstack_carries(carry, len(carries)), losses


def fedcvt_sessions_seeds(extractors_per_seed, classifiers, hp,
                          carries: Sequence[Any], xs_per_seed, ys,
                          schedules, xs_u_per_seed, u_schedules,
                          mode: str = "auto", mesh=None):
    """S seeds of one FedCVT-style session as ONE folded program; the
    per-party unaligned pools and their draw schedules stack on the same
    seed axis. Returns ``(per-seed carries, (S, iters) losses)``."""
    from repro.engine import iterative        # deferred: sibling module

    _assert_seed_models_equal(extractors_per_seed, classifiers)
    exts, clf = extractors_per_seed[0], classifiers[0]
    num_parties = len(u_schedules[0])
    carry, losses = iterative.run_iterative_session_seeds(
        iterative.session_cache_key("fedcvt", exts, clf, hp),
        lambda: iterative.make_fedcvt_step_fn(exts, clf, hp),
        stack_carries(carries), _stack_party_data(xs_per_seed),
        jnp.stack(list(ys)), jnp.stack(list(schedules)), mode,
        xs_u=_stack_party_data(xs_u_per_seed),
        u_schedules=tuple(jnp.stack([us[k] for us in u_schedules])
                          for k in range(num_parties)), mesh=mesh)
    return unstack_carries(carry, len(carries)), losses


def fedbcd_sessions_seeds(extractors_per_seed, classifiers, hp, q: int,
                          carries: Sequence[Any], xs_per_seed, ys,
                          schedules, mode: str = "auto", mesh=None):
    """S seeds of one FedBCD-p session (Q local updates per round) as ONE
    folded program. Returns ``(per-seed carries, (S, rounds) losses)``."""
    from repro.engine import iterative        # deferred: sibling module

    _assert_seed_models_equal(extractors_per_seed, classifiers)
    exts, clf = extractors_per_seed[0], classifiers[0]
    carry, losses = iterative.run_iterative_session_seeds(
        iterative.session_cache_key("fedbcd", exts, clf, hp, q),
        lambda: iterative.make_fedbcd_step_fn(exts, clf, hp, q),
        stack_carries(carries), _stack_party_data(xs_per_seed),
        jnp.stack(list(ys)), jnp.stack(list(schedules)), mode, mesh=mesh)
    return unstack_carries(carry, len(carries)), losses


# --------------------------------------------- server fits: vmapped sessions
def fit_sessions_batched(model, lr: float, params_list: Sequence[Any],
                         xs: Sequence[jnp.ndarray], ys: Sequence[jnp.ndarray],
                         schedules: Sequence[jnp.ndarray],
                         mesh=None) -> List[Any]:
    """A batch of server classifier fits as ONE cached vmapped ``lax.scan``
    session (domain ``"server_fit"``, keyed next to the plain session).

    Every entry must share the (x, y, schedule) shapes — true by
    construction for one scenario point's seeds, whose schedules differ
    only in *contents* (they travel as arguments). Entries may belong to
    different seeds or different aux-classifier parties alike: the batch
    axis is anonymous, exactly like the SSL fold's."""
    from repro.core.server import _fit_session        # deferred: core imports engine

    mesh = parallel.resolve_mesh(mesh)
    n = len(params_list)
    fitv = sessions.cached_session(
        "server_fit", ("vmap", sessions.model_key(model), float(lr),
                       parallel.mesh_key(mesh)),
        lambda: parallel.shard_jit(jax.vmap(_fit_session(model, lr)), mesh))
    stacked = jax.tree_util.tree_map(
        lambda *a: jnp.stack(a), *parallel.pad_entries(params_list, mesh))
    out = fitv(stacked, jnp.stack(parallel.pad_entries(xs, mesh)),
               jnp.stack(parallel.pad_entries(ys, mesh)),
               jnp.stack(parallel.pad_entries(schedules, mesh)))
    return [jax.tree_util.tree_map(lambda a: a[i], out) for i in range(n)]
