"""The shared compiled-session cache (DESIGN.md §9).

Every whole-session jitted program in the repo — the iterative baselines'
``lax.scan`` sessions (``engine.iterative``), the one-shot/few-shot local-SSL
sessions (``engine.local_ssl``), and the server classifier fits
(``core.server._fit``) — is built once per *semantic* step identity and
re-served from here on every later call. Training data always travels as
arguments, never inside the cached closure, so one compiled program serves
every seed and every scenario point of equal shapes; ``jax.jit``'s own
shape-specialization handles the rest.

Cache keys combine:

* ``model_key(model)`` — the semantic identity of a ``Model``: the apply
  function's code object plus its captured closure values (the guarantee
  ``local_ssl._apply_fns_match`` relies on). Two
  ``make_mlp_extractor(rep_dim=16, hidden=(32,))`` calls return distinct
  closures with equal keys, so sessions built for one re-serve the other.
* hashable hyper-parameter records (frozen dataclasses like ``SSLHParams``
  / ``IterHParams`` / ``SSLConfig``, plain floats/ints/bools).

Hit/miss counters are tracked per *domain* (the first element of every
cache key: ``"iterative"``, ``"ssl"``, ``"server_fit"``, ``"kmeans"``) so
benchmarks can report compile counts per subsystem and tests can pin the
no-recompile contract without cross-talk
(``session_cache_stats(domain=...)``).

Because keys never encode batch width, the seed-batched folds of
DESIGN.md §10 (``engine.batched``) re-serve the same cached programs at
any stacked S·K shape — multi-seed sweeps add zero fresh session builds
beyond the first seed.
"""
from __future__ import annotations

import hashlib
from typing import Any, Callable, Dict, Optional

import numpy as np

from repro.models.extractors import Model

_SESSION_CACHE: Dict[tuple, Any] = {}
_CACHE_STATS: Dict[str, Dict[str, int]] = {}


def _domain_stats(domain: str) -> Dict[str, int]:
    return _CACHE_STATS.setdefault(domain, {"hits": 0, "misses": 0})


def session_cache_stats(domain: Optional[str] = None) -> Dict[str, int]:
    """Aggregate ``{"hits": .., "misses": ..}``; pass ``domain`` to restrict
    to one subsystem ("iterative" | "ssl" | "server_fit")."""
    if domain is not None:
        return dict(_domain_stats(domain))
    out = {"hits": 0, "misses": 0}
    for st in _CACHE_STATS.values():
        out["hits"] += st["hits"]
        out["misses"] += st["misses"]
    return out


def session_cache_stats_by_domain() -> Dict[str, Dict[str, int]]:
    """Per-domain hit/miss counters (what ``benchmarks/frontier.py``
    serializes into ``BENCH_frontier.json``)."""
    return {d: dict(st) for d, st in sorted(_CACHE_STATS.items())}


def clear_session_cache() -> None:
    _SESSION_CACHE.clear()
    _CACHE_STATS.clear()


def model_key(m: Model) -> tuple:
    """Semantic identity of a Model: apply-fn code + captured closure values.

    Parameters travel as arguments, never in the closure, so equal code +
    equal closure cells ⇒ the same pure forward function."""
    fn = m.apply
    cells = []
    for c in (fn.__closure__ or ()):
        v = c.cell_contents
        try:
            hash(v)
            cells.append(v)
        except TypeError:
            try:
                # arrays: digest the full contents — repr() truncates large
                # arrays, which could alias two different constants onto one
                # cache key and silently re-serve the wrong program
                arr = np.asarray(v)
                if arr.dtype == object:
                    raise TypeError("not a numeric array")
                cells.append(("arr", arr.shape, str(arr.dtype),
                              hashlib.sha1(arr.tobytes()).hexdigest()))
            except Exception:
                # un-digestable cell (dict/object closures): a fresh token
                # guarantees a cache MISS — recompiling is safe, re-serving
                # another model's program is not (and repr()/pointer bytes
                # can collide across gc'd addresses)
                cells.append(object())
    return (getattr(fn, "__code__", None), tuple(cells), m.rep_dim)


def cached_session(domain: str, key: tuple, builder: Callable[[], Any]) -> Any:
    """Return the compiled callable cached under ``(domain,) + key``,
    building (and counting a miss for ``domain``) on first use."""
    full = (domain,) + key
    fn = _SESSION_CACHE.get(full)
    stats = _domain_stats(domain)
    if fn is None:
        stats["misses"] += 1
        fn = builder()
        _SESSION_CACHE[full] = fn
    else:
        stats["hits"] += 1
    return fn
