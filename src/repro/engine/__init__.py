"""VFL engine layer — the shared jit-compiled multi-client training path.

``repro.core.protocol`` (host-scale orchestration) and
``repro.launch.vfl_step`` (multi-pod shard_map schedule) both build their
local-SSL training from the step functions defined here, so the paper's
"all client computation happens between the exchanges" claim is one
implementation, not two. See DESIGN.md §2.

Multi-seed scenario sweeps fold into the same machinery: the vmapped
session's client axis is a plain batch axis, so ``engine.batched`` stacks
S seeds × K parties into one S·K-entry program (DESIGN.md §10).

Kernel dispatch for the protocol's two Pallas hot-spots (k-means assignment,
SDPA estimation) is funneled through :func:`pseudo_labels` /
:func:`estimate_missing` and their fold-native batched counterparts
:func:`pseudo_labels_batched` / :func:`estimate_missing_batched` behind a
single ``use_kernels`` switch — the batched entries serve a whole stacked
fold as ONE Pallas grid launch (DESIGN.md §15).
"""
from repro.engine.local_ssl import (
    PartyParams,
    PartyTask,
    Schedule,
    SSLHParams,
    build_schedule,
    make_ssl_optimizer,
    make_ssl_step_fn,
    parties_are_homogeneous,
    schedule_steps,
    tasks_are_homogeneous,
    train_clients_ssl,
    train_parties_ssl_vmapped,
    train_party_ssl,
)
from repro.engine.dispatch import (
    estimate_missing,
    estimate_missing_batched,
    estimate_missing_fused,
    pseudo_labels,
    pseudo_labels_batched,
)
from repro.engine import batched, iterative, parallel, sessions
from repro.engine.parallel import device_fold, mesh_key, resolve_mesh
from repro.engine.batched import (
    fedbcd_sessions_seeds,
    fedcvt_sessions_seeds,
    fewshot_probs_seeds,
    fit_sessions_batched,
    flatten_seed_tasks,
    pseudo_labels_seeds,
    splitnn_sessions_seeds,
    stack_carries,
    train_clients_ssl_seeds,
    unflatten_seed_results,
    unstack_carries,
)
from repro.engine.sessions import (clear_session_cache, session_cache_stats,
                                   session_cache_stats_by_domain)

__all__ = [
    "batched",
    "iterative",
    "parallel",
    "sessions",
    "clear_session_cache",
    "device_fold",
    "mesh_key",
    "resolve_mesh",
    "session_cache_stats",
    "session_cache_stats_by_domain",
    "PartyParams",
    "PartyTask",
    "Schedule",
    "SSLHParams",
    "build_schedule",
    "estimate_missing",
    "estimate_missing_batched",
    "estimate_missing_fused",
    "fedbcd_sessions_seeds",
    "fedcvt_sessions_seeds",
    "fewshot_probs_seeds",
    "fit_sessions_batched",
    "flatten_seed_tasks",
    "make_ssl_optimizer",
    "make_ssl_step_fn",
    "parties_are_homogeneous",
    "pseudo_labels",
    "pseudo_labels_batched",
    "pseudo_labels_seeds",
    "schedule_steps",
    "splitnn_sessions_seeds",
    "stack_carries",
    "tasks_are_homogeneous",
    "train_clients_ssl",
    "train_clients_ssl_seeds",
    "train_parties_ssl_vmapped",
    "train_party_ssl",
    "unflatten_seed_results",
    "unstack_carries",
]
