"""VFL engine layer — the shared jit-compiled multi-client training path.

``repro.core.protocol`` (host-scale orchestration) and
``repro.launch.vfl_step`` (multi-pod shard_map schedule) both build their
local-SSL training from the step functions defined here, so the paper's
"all client computation happens between the exchanges" claim is one
implementation, not two. See DESIGN.md §2.

Kernel dispatch for the protocol's two Pallas hot-spots (k-means assignment,
SDPA estimation) is funneled through :func:`pseudo_labels` and
:func:`estimate_missing` behind a single ``use_kernels`` switch.
"""
from repro.engine.local_ssl import (
    PartyParams,
    PartyTask,
    Schedule,
    SSLHParams,
    build_schedule,
    make_ssl_optimizer,
    make_ssl_step_fn,
    tasks_are_homogeneous,
    train_clients_ssl,
    train_parties_ssl_vmapped,
    train_party_ssl,
)
from repro.engine.dispatch import estimate_missing, pseudo_labels
from repro.engine import iterative, sessions
from repro.engine.sessions import (clear_session_cache, session_cache_stats,
                                   session_cache_stats_by_domain)

__all__ = [
    "iterative",
    "sessions",
    "clear_session_cache",
    "session_cache_stats",
    "session_cache_stats_by_domain",
    "PartyParams",
    "PartyTask",
    "Schedule",
    "SSLHParams",
    "build_schedule",
    "estimate_missing",
    "make_ssl_optimizer",
    "make_ssl_step_fn",
    "pseudo_labels",
    "tasks_are_homogeneous",
    "train_clients_ssl",
    "train_parties_ssl_vmapped",
    "train_party_ssl",
]
