"""Partition-spec assignment for parameter / optimizer / activation trees.

Baseline policy (the §Perf hillclimb iterates from here):

* Parameters: 2D tensor-parallel × FSDP — for each leaf the largest
  divisible dim is sharded over ``model`` and the next largest divisible dim
  over ``data``. Leading layer-stack dims (scan axes) are never sharded.
  Multi-pod: parameters are replicated across ``pod`` (each pod = one VFL
  party holding a full copy; batch is pod-split).
* Batches: global batch over (``pod``, ``data``) when divisible, else
  ``data``, else replicated. Sequence stays unsharded for train (activations
  shard over batch); decode caches shard their length dim over ``data`` and
  head/feature dims over ``model`` via the same largest-dim rule.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# containers whose children carry leading layer-stack dims
_STACK1 = ("blocks", "enc_blocks", "dec_blocks", "rest")
_STACK2 = ("super",)


def _path_names(path) -> list:
    names = []
    for p in path:
        if hasattr(p, "key"):
            names.append(str(p.key))
        elif hasattr(p, "name"):
            names.append(str(p.name))
        elif hasattr(p, "idx"):
            names.append(f"[{p.idx}]")
    return names


def _stack_depth(names: Sequence[str]) -> int:
    if any(n in _STACK2 for n in names):
        return 2
    if any(n in _STACK1 for n in names):
        return 1
    return 0


def param_spec(names: Sequence[str], shape: Tuple[int, ...], mesh: Mesh,
               fsdp_only: bool = False, embed_single_axis: bool = False) -> P:
    """fsdp_only: no tensor-parallel ('model') sharding; the FSDP shard goes
    on the INPUT (first body) dim so matmul contractions meet a sharded dim
    on the weight side only — SPMD then all-gathers the (small) weight rather
    than all-reducing the (huge) activation partial sums (§Perf B3).

    embed_single_axis: embedding/unembedding tables shard the vocab dim over
    'model' ONLY — sharding d_model over 'data' makes every logits matmul a
    partial-sum all-reduce of the (B, S, V) tensor (§Perf B3)."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_n = axis_sizes.get("model", 1)
    data_n = axis_sizes.get("data", 1)
    depth = min(_stack_depth(names), len(shape))
    body = list(shape[depth:])
    spec: list = [None] * len(shape)
    if not body:
        return P(*spec)

    is_embed = any(n in ("tok", "unembed") for n in names)
    if is_embed and embed_single_axis:
        order = sorted(range(len(body)), key=lambda i: -body[i])
        for i in order:
            if body[i] % model_n == 0 and body[i] >= model_n:
                spec[depth + i] = "model"
                break
        return P(*spec)

    order = sorted(range(len(body)), key=lambda i: -body[i])
    used = set()
    if not fsdp_only:
        # largest divisible dim → model
        for i in order:
            if body[i] % model_n == 0 and body[i] >= model_n:
                spec[depth + i] = "model"
                used.add(i)
                break
        # next largest divisible dim → data
        for i in order:
            if i in used:
                continue
            if body[i] % data_n == 0 and body[i] >= data_n:
                spec[depth + i] = "data"
                break
    else:
        # input-dim-first FSDP
        for i in list(range(len(body))) :
            if i not in used and body[i] % data_n == 0 and body[i] >= data_n:
                spec[depth + i] = "data"
                break
    return P(*spec)


def shard_params(shapes_tree, mesh: Mesh, fsdp_only_paths: Tuple[str, ...] = (),
                 embed_single_axis: bool = False):
    """ShapeDtypeStruct tree → NamedSharding tree (same structure).

    fsdp_only_paths: leaves whose path contains any of these names get
    data-only input-dim sharding (no tensor parallelism)."""
    def one(path, leaf):
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        names = _path_names(path)
        fsdp_only = any(n in fsdp_only_paths for n in names)
        return NamedSharding(mesh, param_spec(names, leaf.shape, mesh,
                                              fsdp_only=fsdp_only,
                                              embed_single_axis=embed_single_axis))

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


def batch_spec(shape: Tuple[int, ...], mesh: Mesh) -> P:
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pod_n = axis_sizes.get("pod", 1)
    data_n = axis_sizes.get("data", 1)
    b = shape[0] if shape else 1
    spec: list = [None] * len(shape)
    if len(shape) == 0:
        return P()
    if pod_n > 1 and b % (pod_n * data_n) == 0:
        spec[0] = ("pod", "data")
    elif b % data_n == 0 and b >= data_n:
        spec[0] = "data"
    return P(*spec)


def shard_batch(spec_tree, mesh: Mesh):
    def one(leaf):
        return NamedSharding(mesh, batch_spec(tuple(leaf.shape), mesh))

    return jax.tree_util.tree_map(one, spec_tree)


def cache_spec(names: Sequence[str], shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Decode caches are stacked (stack_dims..., batch, length, heads/feat...).

    Batch dim over (pod,data) when divisible; the largest divisible trailing
    dim (after the length dim) over ``model``; the length dim is never
    sharded — it is updated by dynamic_update_slice at token granularity."""
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    pod_n = axis_sizes.get("pod", 1)
    data_n = axis_sizes.get("data", 1)
    model_n = axis_sizes.get("model", 1)
    if len(shape) == 0:
        return P()
    depth = min(_stack_depth(names), len(shape) - 1)
    spec: list = [None] * len(shape)
    b = shape[depth]
    if pod_n > 1 and b % (pod_n * data_n) == 0 and b >= pod_n * data_n:
        spec[depth] = ("pod", "data")
    elif b % data_n == 0 and b >= data_n:
        spec[depth] = "data"
    # trailing feature/head dims (skip the length dim at depth+1)
    best = None
    for i in range(len(shape) - 1, depth + 1, -1):
        if shape[i] % model_n == 0 and shape[i] >= model_n:
            if best is None or shape[i] > shape[best]:
                best = i
    if best is not None:
        spec[best] = "model"
    return P(*spec)


def shard_cache(shapes_tree, mesh: Mesh):
    def one(path, leaf):
        names = _path_names(path)
        if not hasattr(leaf, "shape") or len(leaf.shape) == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, cache_spec(names, leaf.shape, mesh))

    return jax.tree_util.tree_map_with_path(one, shapes_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())
