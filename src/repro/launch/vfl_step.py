"""The paper's protocol as a multi-pod collective schedule (DESIGN.md §3).

Each pod is one VFL party: party-private features and extractor weights live
in that pod (sharded over the pod's own data/model axes); true labels live
with the "server" which we co-locate with party 0. The *only* tensors that
may cross the pod axis are the ones the protocol exchanges:

  vanilla VFL   : per training step — all-gather of minibatch representations
                  (+ the implicit partial-grad return inside the same jitted
                  step), i.e. Θ(steps) pod-crossing collectives;
  one-shot VFL  : the whole session is ONE jitted program with exactly three
                  rep/grad exchanges; all local-SSL iterations run inside a
                  lax.fori_loop with zero pod-axis communication.

Both schedules are expressed with shard_map over the "pod" axis so the
dry-run's HLO makes the collective-count difference inspectable — this is
the paper's 330× communication claim restated in collectives.

The party-local computation is NOT a toy re-implementation: the extractor is
``repro.models.make_mlp_extractor``, the pseudo-labels come from the real
jittable k-means (``repro.core.clustering``), and the SSL iterations inside
the fori_loop are the engine's ``make_ssl_step_fn`` — the same step function
``repro.core.protocol`` trains with (DESIGN.md §2). The collective counts
below are therefore measured against the real local training program.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import clustering
from repro.core.ssl import SSLConfig, cross_entropy
from repro.engine.local_ssl import (PartyParams, SSLHParams, make_ssl_optimizer,
                                    make_ssl_step_fn)
from repro.models.extractors import make_classifier, make_mlp_extractor


def _make_extractor(feat_dim: int, hidden: int, rep_dim: int):
    del feat_dim  # the apply fn reads the input dim from the params
    return make_mlp_extractor(rep_dim=rep_dim, hidden=(hidden,))


def extractor_shapes(feat_dim: int, hidden: int, rep_dim: int, parties: int):
    """ShapeDtypeStructs of the per-party extractor params (leading pod dim),
    matching ``make_mlp_extractor(rep_dim, hidden=(hidden,))``'s pytree."""
    return {
        "w0": jax.ShapeDtypeStruct((parties, feat_dim, hidden), jnp.float32),
        "b0": jax.ShapeDtypeStruct((parties, hidden), jnp.float32),
        "w1": jax.ShapeDtypeStruct((parties, hidden, rep_dim), jnp.float32),
        "b1": jax.ShapeDtypeStruct((parties, rep_dim), jnp.float32),
    }


def make_vanilla_vfl_step(mesh: Mesh, feat_dim: int, hidden: int, rep_dim: int,
                          num_classes: int, lr: float = 0.01) -> Callable:
    """One SplitNN iteration: reps all-gather across pods, joint loss, local
    backprop. Inputs carry a leading party axis sharded over "pod"."""
    ext = _make_extractor(feat_dim, hidden, rep_dim)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("pod"), P("pod", "data"), P("data"), P(None, None)),
        out_specs=(P("pod"), P()),
        check_rep=False)
    def step(params, x, y, w_head):
        # params leaves (1, f, h) locally; x (1, b_local, f)
        wp = jax.tree_util.tree_map(lambda a: a[0], params)
        xl = x[0]

        def loss_fn(wp):
            rep = ext.apply(wp, xl)                         # (b, r)
            # ① upload: all-gather representations across parties (pod axis)
            reps = jax.lax.all_gather(rep, "pod")           # (K, b, r)
            joint = jnp.moveaxis(reps, 0, 1).reshape(xl.shape[0], -1)
            logits = joint @ w_head
            return jnp.mean(cross_entropy(logits, y))

        # ② the partial-grad return is the transpose of the all-gather
        loss, grads = jax.value_and_grad(loss_fn)(wp)
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, wp, grads)
        new = jax.tree_util.tree_map(lambda a: a[None], new)
        return new, jnp.array([loss])[0]

    return step


def make_oneshot_vfl_session(mesh: Mesh, feat_dim: int, hidden: int,
                             rep_dim: int, num_classes: int,
                             local_steps: int, lr: float = 0.01,
                             rep_dtype=jnp.float32,
                             kmeans_iters: int = 8,
                             ssl_cfg: SSLConfig = SSLConfig(modality="tabular"),
                             ) -> Callable:
    """The WHOLE one-shot session as one program with exactly 3 pod-axis
    exchanges: reps up → partial grads down → refreshed reps up. Everything
    between the exchanges is party-local: the real jittable k-means over the
    returned partial gradients (Alg. 1 l.28, restarts=1 to keep the compiled
    program lean) and ``local_steps`` iterations of the engine's SSL step —
    full-batch FixMatch-tab on (overlap ∘ pseudo-labels, private pool) — in
    a lax.fori_loop with zero collectives inside."""
    ext = _make_extractor(feat_dim, hidden, rep_dim)
    head = make_classifier(num_classes)
    tx = make_ssl_optimizer(SSLHParams(epochs=0, learning_rate=lr))
    ssl_step = make_ssl_step_fn(ext, head, ssl_cfg, tx)

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("pod"), P("pod", "data"), P("pod", "data"),
                  P("data"), P(None, None)),
        out_specs=(P("pod"), P()),
        check_rep=False)
    def session(params, x_o, x_u, y, w_head):
        wp = jax.tree_util.tree_map(lambda a: a[0], params)
        xo, xu = x_o[0], x_u[0]
        my = jax.lax.axis_index("pod")

        # ①: upload overlap reps (all-gather = pod exchange #1) — §Perf C:
        # the exchange payload travels in rep_dtype (bf16 halves inter-pod
        # bytes; the paper's accounting assumes f32)
        rep_o = ext.apply(wp, xo)
        # optimization_barrier keeps the cast from being folded away by the
        # excess-precision simplifier — the wire format really is rep_dtype
        rep_q = jax.lax.optimization_barrier(rep_o.astype(rep_dtype))
        reps = jax.lax.optimization_barrier(
            jax.lax.all_gather(rep_q, "pod"))   # exchange 1
        joint = jnp.moveaxis(reps, 0, 1).reshape(xo.shape[0], -1).astype(jnp.float32)

        # ②: partial gradients of the server loss wrt local reps — computed
        # where the labels are and returned to each party (exchange #2 is the
        # transpose of the gather; expressed via psum of the masked grad)
        def server_loss(j):
            return jnp.mean(cross_entropy(j @ w_head, y))

        g_joint = jax.grad(server_loss)(joint)              # (b, K·r)
        g_local = jax.lax.dynamic_slice_in_dim(g_joint, my * rep_dim, rep_dim, 1)
        g_q = jax.lax.optimization_barrier(g_local.astype(rep_dtype))
        g_local = (jax.lax.optimization_barrier(jax.lax.psum(g_q, "pod"))
                   / jax.lax.psum(1, "pod")).astype(jnp.float32)  # exchange 2

        # ③: pseudo-labels — the REAL gradient k-means (party-local; the
        # whole Lloyd loop runs inside this program with no collectives)
        k_km = jax.random.fold_in(jax.random.PRNGKey(0), my)
        pseudo = clustering.gradient_pseudo_labels(
            k_km, g_local, num_classes, kmeans_iters, use_kernel=False,
            restarts=1)

        # ④: LOCAL SSL via the engine step — zero pod-axis collectives
        # inside this loop. Full-batch: labeled = (overlap, pseudo),
        # unlabeled = the party-private pool.
        h_params = head.init(jax.random.fold_in(jax.random.PRNGKey(1), my),
                             ext.apply(wp, xo[:1]))
        fm = jnp.mean(xu, axis=0)            # party-local x̄ for FixMatch-tab
        pp = PartyParams(wp, h_params)
        opt_state = tx.init(pp)
        k_ssl = jax.random.fold_in(jax.random.PRNGKey(2), my)

        def local_step(i, carry):
            pp, opt_state = carry
            pp, opt_state, _ = ssl_step(pp, opt_state, fm,
                                        jax.random.fold_in(k_ssl, i),
                                        xo, pseudo, xu)
            return pp, opt_state

        pp, _ = jax.lax.fori_loop(0, local_steps, local_step, (pp, opt_state))
        wp = pp.extractor

        # ⑤: refreshed overlap reps up (exchange #3)
        rep_o2 = ext.apply(wp, xo)
        rep2_q = jax.lax.optimization_barrier(rep_o2.astype(rep_dtype))
        reps2 = jax.lax.optimization_barrier(
            jax.lax.all_gather(rep2_q, "pod"))  # exchange 3
        joint2 = jnp.moveaxis(reps2, 0, 1).reshape(xo.shape[0], -1).astype(jnp.float32)
        final_loss = jnp.mean(cross_entropy(joint2 @ w_head, y))

        wp = jax.tree_util.tree_map(lambda a: a[None], wp)
        return wp, final_loss

    return session


def count_pod_collectives(compiled_text: str, parties: int = 2) -> Dict[str, int]:
    """Count collectives (and their payload bytes) whose replica groups span
    pods, vs pod-internal ones."""
    import re
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1}
    pod_crossing = 0
    internal = 0
    crossing_bytes = 0
    for m in re.finditer(
            r"= ([a-z0-9]+)\[([0-9,]*)\][^\n]*?(all-gather|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute)[^\n]*"
            r"replica_groups=\{\{([0-9,]+)", compiled_text):
        dt, dims, kind, group_s = m.groups()
        group = [int(v) for v in group_s.split(",")]
        if len(group) >= 2 and max(group) - min(group) >= 256:
            pod_crossing += 1
            n = 1
            for d in (dims.split(",") if dims else []):
                n *= int(d)
            crossing_bytes += n * dtype_bytes.get(dt, 4)
        else:
            internal += 1
    return {"pod_crossing": pod_crossing, "pod_internal": internal,
            "pod_crossing_bytes": crossing_bytes}
