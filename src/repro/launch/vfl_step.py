"""The paper's protocol as a multi-pod collective schedule (DESIGN.md §3).

Each pod is one VFL party: party-private features and extractor weights live
in that pod (sharded over the pod's own data/model axes); true labels live
with the "server" which we co-locate with party 0. The *only* tensors that
may cross the pod axis are the ones the protocol exchanges:

  vanilla VFL   : per training step — all-gather of minibatch representations
                  (+ the implicit partial-grad return inside the same jitted
                  step), i.e. Θ(steps) pod-crossing collectives;
  one-shot VFL  : the whole session is ONE jitted program with exactly three
                  rep/grad exchanges; all local-SSL iterations run inside a
                  lax.fori_loop with zero pod-axis communication.

Both schedules are expressed with shard_map over the "pod" axis so the
dry-run's HLO makes the collective-count difference inspectable — this is
the paper's 330× communication claim restated in collectives.
"""
from __future__ import annotations

import functools
from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.ssl import cross_entropy


# --------------------------------------------------------------------------
# a tiny party-local extractor (MLP) — weights are per-party (leading pod dim)
# --------------------------------------------------------------------------
def extractor_shapes(feat_dim: int, hidden: int, rep_dim: int, parties: int):
    return {
        "w0": jax.ShapeDtypeStruct((parties, feat_dim, hidden), jnp.float32),
        "w1": jax.ShapeDtypeStruct((parties, hidden, rep_dim), jnp.float32),
    }


def _extract(wp, x):       # wp: {w0 (f,h), w1 (h,r)}, x (b, f)
    return jax.nn.relu(x @ wp["w0"]) @ wp["w1"]


def make_vanilla_vfl_step(mesh: Mesh, feat_dim: int, hidden: int, rep_dim: int,
                          num_classes: int, lr: float = 0.01) -> Callable:
    """One SplitNN iteration: reps all-gather across pods, joint loss, local
    backprop. Inputs carry a leading party axis sharded over "pod"."""
    parties = mesh.devices.shape[mesh.axis_names.index("pod")]

    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("pod"), P("pod", "data"), P("data"), P(None, None)),
        out_specs=(P("pod"), P()),
        check_rep=False)
    def step(params, x, y, w_head):
        # params leaves (1, f, h) locally; x (1, b_local, f)
        wp = jax.tree_util.tree_map(lambda a: a[0], params)
        xl = x[0]

        def loss_fn(wp):
            rep = _extract(wp, xl)                          # (b, r)
            # ① upload: all-gather representations across parties (pod axis)
            reps = jax.lax.all_gather(rep, "pod")           # (K, b, r)
            joint = jnp.moveaxis(reps, 0, 1).reshape(xl.shape[0], -1)
            logits = joint @ w_head
            return jnp.mean(cross_entropy(logits, y))

        # ② the partial-grad return is the transpose of the all-gather
        loss, grads = jax.value_and_grad(loss_fn)(wp)
        new = jax.tree_util.tree_map(lambda p, g: p - lr * g, wp, grads)
        new = jax.tree_util.tree_map(lambda a: a[None], new)
        return new, jnp.array([loss])[0]

    return step


def make_oneshot_vfl_session(mesh: Mesh, feat_dim: int, hidden: int,
                             rep_dim: int, num_classes: int,
                             local_steps: int, lr: float = 0.01,
                             rep_dtype=jnp.float32) -> Callable:
    """The WHOLE one-shot session as one program with exactly 3 pod-axis
    exchanges: reps up → pseudo-label signal down → refreshed reps up.
    The k-means/SSL machinery is the full repro.core implementation at host
    scale; here the schedule is the point — local training is a fori_loop
    with no collectives inside."""
    @functools.partial(
        shard_map, mesh=mesh,
        in_specs=(P("pod"), P("pod", "data"), P("pod", "data"),
                  P("data"), P(None, None)),
        out_specs=(P("pod"), P()),
        check_rep=False)
    def session(params, x_o, x_u, y, w_head):
        wp = jax.tree_util.tree_map(lambda a: a[0], params)
        xo, xu = x_o[0], x_u[0]

        # ①: upload overlap reps (all-gather = pod exchange #1) — §Perf C:
        # the exchange payload travels in rep_dtype (bf16 halves inter-pod
        # bytes; the paper's accounting assumes f32)
        rep_o = _extract(wp, xo)
        # optimization_barrier keeps the cast from being folded away by the
        # excess-precision simplifier — the wire format really is rep_dtype
        rep_q = jax.lax.optimization_barrier(rep_o.astype(rep_dtype))
        reps = jax.lax.optimization_barrier(
            jax.lax.all_gather(rep_q, "pod"))   # exchange 1
        joint = jnp.moveaxis(reps, 0, 1).reshape(xo.shape[0], -1).astype(jnp.float32)

        # ②: partial gradients of the server loss wrt local reps — computed
        # where the labels are and returned to each party (exchange #2 is the
        # transpose of the gather; expressed via psum of the masked grad)
        def server_loss(j):
            return jnp.mean(cross_entropy(j @ w_head, y))

        g_joint = jax.grad(server_loss)(joint)              # (b, K·r)
        my = jax.lax.axis_index("pod")
        g_local = jax.lax.dynamic_slice_in_dim(g_joint, my * rep_dim, rep_dim, 1)
        g_q = jax.lax.optimization_barrier(g_local.astype(rep_dtype))
        g_local = (jax.lax.optimization_barrier(jax.lax.psum(g_q, "pod"))
                   / jax.lax.psum(1, "pod")).astype(jnp.float32)  # exchange 2

        # ③: pseudo-labels from the gradient signal (sign-projection proxy of
        # the k-means step — same information content, jit-static shape)
        pseudo = jnp.argmax(g_local @ jax.random.normal(
            jax.random.PRNGKey(0), (rep_dim, num_classes)), axis=-1)

        # ④: LOCAL SSL — zero pod-axis collectives inside this loop
        def local_step(i, wp):
            def ssl_loss(wp):
                z_o = _extract(wp, xo)
                logit_o = z_o @ jax.random.normal(jax.random.PRNGKey(1),
                                                  (rep_dim, num_classes))
                l_s = jnp.mean(cross_entropy(logit_o, pseudo))
                z_u = _extract(wp, xu)
                l_u = jnp.mean(jnp.square(z_u - jax.lax.stop_gradient(
                    jnp.roll(z_u, 1, axis=0))))             # consistency proxy
                return l_s + 0.1 * l_u
            g = jax.grad(ssl_loss)(wp)
            return jax.tree_util.tree_map(lambda p, gg: p - lr * gg, wp, g)

        wp = jax.lax.fori_loop(0, local_steps, local_step, wp)

        # ⑤: refreshed overlap reps up (exchange #3)
        rep_o2 = _extract(wp, xo)
        rep2_q = jax.lax.optimization_barrier(rep_o2.astype(rep_dtype))
        reps2 = jax.lax.optimization_barrier(
            jax.lax.all_gather(rep2_q, "pod"))  # exchange 3
        joint2 = jnp.moveaxis(reps2, 0, 1).reshape(xo.shape[0], -1).astype(jnp.float32)
        final_loss = jnp.mean(cross_entropy(joint2 @ w_head, y))

        wp = jax.tree_util.tree_map(lambda a: a[None], wp)
        return wp, final_loss

    return session


def count_pod_collectives(compiled_text: str, parties: int = 2) -> Dict[str, int]:
    """Count collectives (and their payload bytes) whose replica groups span
    pods, vs pod-internal ones."""
    import re
    dtype_bytes = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "pred": 1}
    pod_crossing = 0
    internal = 0
    crossing_bytes = 0
    for m in re.finditer(
            r"= ([a-z0-9]+)\[([0-9,]*)\][^\n]*?(all-gather|all-reduce|"
            r"reduce-scatter|all-to-all|collective-permute)[^\n]*"
            r"replica_groups=\{\{([0-9,]+)", compiled_text):
        dt, dims, kind, group_s = m.groups()
        group = [int(v) for v in group_s.split(",")]
        if len(group) >= 2 and max(group) - min(group) >= 256:
            pod_crossing += 1
            n = 1
            for d in (dims.split(",") if dims else []):
                n *= int(d)
            crossing_bytes += n * dtype_bytes.get(dt, 4)
        else:
            internal += 1
    return {"pod_crossing": pod_crossing, "pod_internal": internal,
            "pod_crossing_bytes": crossing_bytes}
