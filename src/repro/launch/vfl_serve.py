"""Online VFL serving: a loaded artifact behind one fused jitted forward.

The deployment side of the paper's claim (DESIGN.md §13): after ~1-2
communication rounds the parties hold a *joint* model, and this module is
what answers queries with it. A :class:`ServingEngine` wraps a
:class:`~repro.checkpoint.artifact.TrainedVFLModel` in ONE jitted forward —
party extractors and the server head fused into a single program, vmapped
over the party axis when ``parties_are_homogeneous`` (equal specs ⇒ one
stacked extractor call, the serving analogue of the engine's training-time
fast path) and Python-composed inside the same jit otherwise — and drives
continuous traffic through the fixed-shape masked batcher of
``launch/batching.py``: requests pad to the engine's capacity, validity
masks neutralize the padding, and input buffers are donated (off-CPU), so
changing traffic never recompiles and steady-state serving allocates no
fresh forward buffers.

The fused program is built through the engine-wide session cache
(``engine/sessions.py``, domain ``"serving"``) under the artifact's model
identity — a key that never encodes batch width — so serving adds exactly
ONE fresh session build per deployed model: every later batch shape, every
re-instantiated engine over the same artifact, re-serves it
(tests/test_serving.py pins the zero-fresh-misses contract).

Kernel routing is roofline-informed (:class:`KernelRouter`): the SDPA
missing-party estimation of Eq. 10 — the serveable Pallas hot-spot, used
when a querying party lacks the other parties' features — routes to the
flash-style blocked kernel only where ``roofline/`` analysis says it beats
XLA (score-matrix working sets past VMEM scale, never under CPU interpret
mode); the zoo-serving thresholds for ``rmsnorm`` (rows·d ≳ a few MB,
kernels/rmsnorm/ops.py) and ``decode_attention`` (S ≳ 8k,
kernels/decode_attention/ops.py) live on the same router.

CLI::

    PYTHONPATH=src python -m repro.launch.vfl_serve \
        --artifact artifacts/hard32 --capacity 64 --requests 32
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.checkpoint.artifact import TrainedVFLModel, load_artifact
from repro.engine.dispatch import estimate_missing_fused
from repro.engine.sessions import cached_session, model_key
from repro.kernels import interpret_mode
from repro.launch import batching

SERVING_DOMAIN = "serving"


@dataclasses.dataclass(frozen=True)
class KernelRouter:
    """Roofline-informed Pallas-vs-XLA routing for the serving hot paths.

    One rule per kernel, each citing the crossover its ops.py derives; on
    CPU (interpret mode) Pallas never wins — interpretation is strictly
    overhead — so everything routes to XLA.
    """

    backend: str
    interpret: bool

    @staticmethod
    def default() -> "KernelRouter":
        return KernelRouter(backend=jax.default_backend(),
                            interpret=interpret_mode())

    @property
    def pallas_viable(self) -> bool:
        return not self.interpret and self.backend == "tpu"

    def use_sdpa(self, n_u: int, n_o: int, d: int, batch: int = 1) -> bool:
        """Eq. 10 estimation: the flash-style blocked kernel wins when the
        score matrices no longer fit VMEM-resident tiles — i.e. when
        materializing softmax(H_u H_oᵀ) costs an extra HBM round-trip
        (kernels/sdpa_estimator). Below that XLA fuses the chain fine.
        ``batch`` is the batched-grid width (a served partial-party query
        runs all K−1 estimates as ONE ``(K−1, …)`` grid launch, so the
        roofline sees the whole B·N_u·N_o score volume, not one slice)."""
        return self.pallas_viable and batch * n_u * n_o * 4 >= 4 << 20

    def use_rmsnorm(self, rows: int, d: int) -> bool:
        """Fused RMSNorm wins on large activations (rows·d ≳ a few MB)
        where XLA's unfused upcast/variance round-trips dominate the
        1R+1W memory floor (kernels/rmsnorm/ops.py)."""
        return self.pallas_viable and rows * d * 4 >= 4 << 20

    def use_decode_attention(self, seq_len: int) -> bool:
        """Flash-decode pays past S ≳ 8k context
        (kernels/decode_attention/ops.py)."""
        return self.pallas_viable and seq_len >= 8192


def _serving_key(art: TrainedVFLModel) -> tuple:
    """The fused forward's session-cache key: the artifact's model identity
    (per-party apply identity + head identity + fusion strategy). No batch
    width, no capacity — one cached program per deployed model."""
    exts = art.extractors()
    clf = art.classifier()
    return (tuple(model_key(e) for e in exts), model_key(clf),
            art.parties_are_homogeneous)


def _build_fused_forward(art: TrainedVFLModel, donate: bool):
    """ONE jitted program: K extractors + joint head. Parameters travel as
    arguments (the session-cache contract), the per-party inputs are donated
    off-CPU (they are per-request scratch), and the validity mask zeroes
    padding logits."""
    exts = art.extractors()
    clf = art.classifier()

    if art.parties_are_homogeneous:
        apply0 = exts[0].apply

        def raw(client_ext_params, server_params, xs, mask):
            stacked = jnp.stack(xs)                       # (K, capacity, ...)
            reps = jax.vmap(apply0)(client_ext_params, stacked)  # (K, B, r)
            # party-major flatten — identical layout to training-time
            # concat_reps, so the head sees exactly the trained geometry
            flat = jnp.transpose(reps, (1, 0, 2)).reshape(reps.shape[1], -1)
            logits = clf.apply(server_params, flat)
            return jnp.where(mask[:, None], logits, 0.0)
    else:

        def raw(client_ext_params, server_params, xs, mask):
            reps = [e.apply(p, x)
                    for e, p, x in zip(exts, client_ext_params, xs)]
            logits = clf.apply(server_params, jnp.concatenate(reps, axis=-1))
            return jnp.where(mask[:, None], logits, 0.0)

    # donating params would free them after the first call; only the
    # per-request inputs (xs, mask) are scratch. CPU donation is a no-op
    # that warns, so gate on backend.
    return jax.jit(raw, donate_argnums=(2, 3) if donate else ())


class ServingEngine:
    """Continuous batched inference over one deployed VFL model."""

    def __init__(self, art: TrainedVFLModel, capacity: int = 64,
                 router: Optional[KernelRouter] = None) -> None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        self.art = art
        self.capacity = int(capacity)
        self.router = router or KernelRouter.default()
        self._donate = jax.default_backend() != "cpu"
        if art.parties_are_homogeneous:
            self._ext_params = jax.tree_util.tree_map(
                lambda *ps: jnp.stack(ps),
                *[p.extractor for p in art.client_params])
        else:
            self._ext_params = [p.extractor for p in art.client_params]

    # ------------------------------------------------------------ forward
    def _fused(self):
        """The session-cached jitted forward (hits/misses visible under
        ``session_cache_stats("serving")``)."""
        donate = self._donate
        return cached_session(SERVING_DOMAIN, _serving_key(self.art),
                              lambda: _build_fused_forward(self.art, donate))

    def step(self, batch: batching.MaskedBatch) -> jnp.ndarray:
        """One fixed-shape forward over a padded batch → (capacity, C)
        logits (padding rows zeroed). The raw unit ``batching.drive``
        times."""
        return self._fused()(self._ext_params, self.art.server_params,
                             batch.xs, batch.mask)

    def predict_logits(self, xs: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """Logits for an arbitrary-size request: chunk to capacity, pad,
        run the fused forward, keep the valid rows. Matches the artifact's
        unbatched reference oracle at 1e-5."""
        parts = []
        for chunk in batching.chunk_requests(xs, self.capacity):
            batch = batching.pad_to_capacity(chunk, self.capacity)
            parts.append(self.step(batch)[:batch.n])
        return (jnp.concatenate(parts, axis=0) if len(parts) > 1
                else parts[0])

    def predict(self, xs: Sequence[jnp.ndarray]) -> jnp.ndarray:
        """Class predictions (argmax over the fused logits)."""
        return jnp.argmax(self.predict_logits(xs), axis=-1)

    # ------------------------------------------- partial-party queries
    def predict_logits_partial(self, x_k: jnp.ndarray,
                               k: int) -> jnp.ndarray:
        """Serve a query where ONLY party ``k``'s features are present:
        estimate every other party's representation from the artifact's
        stored overlap reps via Eq. 10 (the few-shot SDPA estimator,
        kernel-routed by the roofline rules), then run the joint head."""
        art = self.art
        if art.overlap_reps is None:
            raise ValueError(
                "artifact carries no overlap_reps — re-export it with "
                "to_artifact(..., split=split) to serve partial-party "
                "queries")
        if not 0 <= k < art.num_parties:
            raise ValueError(f"party index {k} out of range "
                             f"[0, {art.num_parties})")
        ext = art.extractors()[k]
        h_u_k = ext.apply(art.client_params[k].extractor, x_k)
        n_o = int(art.overlap_reps[0].shape[0])
        use_kernels = self.router.use_sdpa(int(h_u_k.shape[0]), n_o,
                                           int(h_u_k.shape[-1]),
                                           batch=art.num_parties - 1)
        # all K−1 missing-party estimates as ONE batched grid launch when
        # the other parties' rep dims agree (DESIGN.md §15)
        estimates = estimate_missing_fused(h_u_k, art.overlap_reps, k,
                                           use_kernels=use_kernels)
        est = iter(estimates)
        reps = [h_u_k if j == k else next(est)
                for j in range(art.num_parties)]
        return art.classifier().apply(art.server_params,
                                      jnp.concatenate(reps, axis=-1))


# ------------------------------------------------------------------- CLI
def synthetic_requests(art: TrainedVFLModel, num_requests: int,
                       batch_size: int, seed: int = 0) -> List[tuple]:
    """Per-party Gaussian feature blocks matching the artifact's declared
    shapes — traffic for demos and latency benchmarks."""
    key = jax.random.PRNGKey(seed)
    reqs = []
    for _ in range(num_requests):
        xs = []
        for shape in art.feature_shapes:
            key, sub = jax.random.split(key)
            xs.append(jax.random.normal(sub, (batch_size,) + tuple(shape)))
        reqs.append(tuple(xs))
    return reqs


def serve_traffic(engine: ServingEngine,
                  requests: Sequence[Sequence[jnp.ndarray]],
                  warmup: int = 1):
    """Drive a request stream through the engine's fused step via the
    shared batcher; returns (outputs, LatencyRecorder)."""
    return batching.drive(engine.step, requests, engine.capacity,
                          warmup=warmup)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--artifact", required=True,
                    help="directory written by save_artifact")
    ap.add_argument("--capacity", type=int, default=64,
                    help="fixed batch capacity (ONE compiled shape)")
    ap.add_argument("--requests", type=int, default=32,
                    help="number of synthetic requests to serve")
    ap.add_argument("--batch-size", type=int, default=None,
                    help="rows per request (default: capacity)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    t0 = time.time()
    art = load_artifact(args.artifact)
    engine = ServingEngine(art, capacity=args.capacity)
    print(f"loaded {args.artifact}: scenario={art.scenario} "
          f"K={art.num_parties} classes={art.num_classes} "
          f"homogeneous={art.parties_are_homogeneous} "
          f"({time.time() - t0:.2f}s)")

    bs = args.batch_size or args.capacity
    reqs = synthetic_requests(art, args.requests, bs, seed=args.seed)
    outs, rec = serve_traffic(engine, reqs)
    s = rec.summary()
    print(f"served {s['rows']} rows in {s['batches']} batches "
          f"(capacity {engine.capacity}): p50={s['p50_ms']:.2f}ms "
          f"p99={s['p99_ms']:.2f}ms throughput={s['rows_per_s']:.0f} rows/s")
    preds = jnp.argmax(outs[0], axis=-1)
    print(f"sample predictions: {preds[:8].tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
