"""Batched model-zoo serving driver: prefill a prompt batch, then decode
greedily. The forward is hoisted into :func:`prefill` / :func:`greedy_decode`
so other drivers (e.g. throughput sweeps) compose them, and timing goes
through the shared ``launch/batching.py`` recorder — the same stopwatch the
VFL serving path (``launch/vfl_serve``) reports p50/p99 with.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --reduce \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import specs as SP
from repro.launch.batching import LatencyRecorder
from repro.launch.steps import make_decode_step
from repro.models.model_zoo import build_model


def make_serving_decode(model):
    """The zoo's jitted serving step: one decode with the cache donated
    (steady-state decoding allocates no fresh KV buffers)."""
    return jax.jit(make_decode_step(model), donate_argnums=(1,))


def prefill(decode, params, cache, prompt, rec: LatencyRecorder = None):
    """Step the decoder over the prompt tokens (cache-exact; the bulk
    ``prefill_fn`` path trades exactness checks for throughput). Returns
    the last-position logits and the filled cache."""
    b, prompt_len = prompt.shape
    logits = None
    for t in range(prompt_len):
        batch = {"token": prompt[:, t:t + 1],
                 "pos": jnp.full((b, 1), t, jnp.int32)}
        logits, cache = _timed_decode(decode, params, cache, batch, rec, b)
    return logits, cache


def greedy_decode(decode, params, cache, logits, start: int, steps: int,
                  rec: LatencyRecorder = None):
    """Greedy continuation for ``steps`` tokens from position ``start``.
    Returns the (b, steps) generated tokens and the advanced cache."""
    b = logits.shape[0]
    generated = []
    for t in range(start, start + steps):
        tok = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(tok)
        batch = {"token": tok, "pos": jnp.full((b, 1), t, jnp.int32)}
        logits, cache = _timed_decode(decode, params, cache, batch, rec, b)
    return jnp.concatenate(generated, axis=1), cache


def _timed_decode(decode, params, cache, batch, rec, rows):
    if rec is None:
        return decode(params, cache, batch)
    import time

    t0 = time.perf_counter()
    logits, cache = decode(params, cache, batch)
    logits.block_until_ready()
    rec.record(time.perf_counter() - t0, rows)
    return logits, cache


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="gemma-7b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    key, kp, kt = jax.random.split(key, 3)
    params = model.init(kp)

    b = args.batch
    cache_len = args.prompt_len + args.gen
    cache = SP.zeros_like_spec(model.cache_shapes(b, cache_len))
    decode = make_serving_decode(model)

    prompt = jax.random.randint(kt, (b, args.prompt_len), 0, cfg.vocab_size)
    if cfg.family == "audio":
        key, ke = jax.random.split(key)
        from repro.models.model_zoo import _encode
        emb = 0.02 * jax.random.normal(ke, (b, cfg.prefix_tokens, cfg.d_model))
        cache["enc_out"] = _encode(params, cfg, emb).astype(cache["enc_out"].dtype)

    rec = LatencyRecorder()
    logits, cache = prefill(decode, params, cache, prompt, rec=rec)
    out, cache = greedy_decode(decode, params, cache, logits,
                               args.prompt_len, args.gen, rec=rec)
    s = rec.summary()
    print(f"arch={cfg.name} generated {out.shape}: "
          f"p50={s['p50_ms']:.2f}ms/step p99={s['p99_ms']:.2f}ms/step "
          f"{s['rows_per_s']:.1f} tok/s")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
