"""Batched serving driver: prefill a prompt batch, then decode greedily.

  PYTHONPATH=src python -m repro.launch.serve --arch gemma-7b --reduce \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch import specs as SP
from repro.launch.steps import make_decode_step
from repro.models.model_zoo import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="gemma-7b")
    ap.add_argument("--reduce", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    key, kp, kt = jax.random.split(key, 3)
    params = model.init(kp)

    b = args.batch
    cache_len = args.prompt_len + args.gen
    cache = SP.zeros_like_spec(model.cache_shapes(b, cache_len))
    decode = jax.jit(make_decode_step(model), donate_argnums=(1,))

    prompt = jax.random.randint(kt, (b, args.prompt_len), 0, cfg.vocab_size)
    if cfg.family == "audio":
        key, ke = jax.random.split(key)
        from repro.models.model_zoo import _encode
        emb = 0.02 * jax.random.normal(ke, (b, cfg.prefix_tokens, cfg.d_model))
        cache["enc_out"] = _encode(params, cfg, emb).astype(cache["enc_out"].dtype)

    # prefill by stepping the decoder over the prompt (cache-exact; a bulk
    # prefill_fn path exists for throughput benchmarking)
    t0 = time.time()
    for t in range(args.prompt_len):
        batch = {"token": prompt[:, t:t + 1],
                 "pos": jnp.full((b, 1), t, jnp.int32)}
        logits, cache = decode(params, cache, batch)
    generated = []
    for t in range(args.prompt_len, cache_len):
        tok = jnp.argmax(logits, axis=-1)[:, None]
        generated.append(tok)
        batch = {"token": tok, "pos": jnp.full((b, 1), t, jnp.int32)}
        logits, cache = decode(params, cache, batch)
    out = jnp.concatenate(generated, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} generated {out.shape} in {dt:.2f}s "
          f"({b * cache_len / dt:.1f} tok/s)")
    print("sample:", out[0][:16].tolist())


if __name__ == "__main__":
    main()
