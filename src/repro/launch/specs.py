"""input_specs — ShapeDtypeStruct stand-ins for every model input.

Provides the per-(arch × input-shape) batch trees for the dry-run (no device
allocation) and the matching random-batch materializer for smoke tests.

Modality frontends are stubs per the brief: [vlm]/[audio] batches carry
precomputed patch/frame embeddings of the right shape.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape


def sds(*shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def train_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    if cfg.family == "vlm":
        p = cfg.prefix_tokens
        return {"tokens": sds(b, s - p), "labels": sds(b, s - p),
                "embeds": sds(b, p, cfg.d_model, dtype=jnp.bfloat16)}
    if cfg.family == "audio":
        p = cfg.prefix_tokens
        return {"tokens": sds(b, s - p), "labels": sds(b, s - p),
                "embeds": sds(b, p, cfg.d_model, dtype=jnp.bfloat16)}
    return {"tokens": sds(b, s), "labels": sds(b, s)}


def prefill_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    spec = train_specs(cfg, shape)
    spec.pop("labels")
    return spec


def decode_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    b = shape.global_batch
    return {"token": sds(b, 1), "pos": sds(b, 1)}


def materialize(key: jax.Array, spec_tree) -> Any:
    """Random batch matching a spec tree (smoke tests)."""
    leaves, treedef = jax.tree_util.tree_flatten(spec_tree)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for leaf, k in zip(leaves, keys):
        if jnp.issubdtype(leaf.dtype, jnp.integer):
            out.append(jax.random.randint(k, leaf.shape, 0, 100).astype(leaf.dtype))
        else:
            out.append(jax.random.normal(k, leaf.shape).astype(leaf.dtype) * 0.02)
    return jax.tree_util.tree_unflatten(treedef, out)


def zeros_like_spec(spec_tree) -> Any:
    return jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype), spec_tree)
