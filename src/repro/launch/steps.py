"""jit-able train / prefill / decode steps for any ArchConfig.

These factories serve the model-zoo training/serving stack; the VFL
protocol's analogous step factory is ``repro.engine.make_ssl_step_fn``
(see the module map in DESIGN.md §6). Both follow the same contract: a
pure ``step(params, opt_state, batch…) -> (params, opt_state, aux)`` that
the caller may jit, scan, or close inside a shard_map program.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ArchConfig
from repro.models.model_zoo import ModelDef


def make_optimizer(cfg: ArchConfig, learning_rate: float = 3e-4,
                   grad_clip: float = 1.0):
    if cfg.optimizer == "sgdm":
        return optim.chain(optim.clip_by_global_norm(grad_clip),
                           optim.sgd(learning_rate, momentum=0.9))
    return optim.chain(optim.clip_by_global_norm(grad_clip),
                       optim.adam(learning_rate))


def make_train_step(model: ModelDef, tx, num_microbatches: int = 1) -> Callable:
    """num_microbatches > 1: gradient accumulation via lax.scan — activations
    for only one microbatch are live at a time (the §Perf memory lever)."""
    if num_microbatches == 1:
        def train_step(params, opt_state, batch):
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
            updates, opt_state = tx.update(grads, opt_state, params)
            params = optim.apply_updates(params, updates)
            return params, opt_state, loss

        return train_step

    def train_step(params, opt_state, batch):
        def split(x):
            b = x.shape[0]
            assert b % num_microbatches == 0, (b, num_microbatches)
            return x.reshape((num_microbatches, b // num_microbatches) + x.shape[1:])

        micro = jax.tree_util.tree_map(split, batch)

        def accum(carry, mb):
            loss_sum, grads_sum = carry
            loss, grads = jax.value_and_grad(model.loss_fn)(params, mb)
            grads_sum = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(a.dtype), grads_sum, grads)
            return (loss_sum + loss, grads_sum), None

        zeros = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss_sum, grads), _ = jax.lax.scan(accum, (jnp.zeros((), jnp.float32), zeros),
                                            micro)
        grads = jax.tree_util.tree_map(lambda g: g / num_microbatches, grads)
        updates, opt_state = tx.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, loss_sum / num_microbatches

    return train_step


def make_prefill_step(model: ModelDef) -> Callable:
    def prefill_step(params, batch):
        return model.prefill_fn(params, batch)

    return prefill_step


def make_decode_step(model: ModelDef) -> Callable:
    def decode_step(params, cache, batch):
        logits, new_cache = model.decode_fn(params, cache, batch)
        return logits, new_cache

    return decode_step


def opt_state_shapes(tx, param_shapes):
    return jax.eval_shape(tx.init, param_shapes)
