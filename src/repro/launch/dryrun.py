"""Multi-pod dry-run: lower + compile every (arch × input-shape × mesh)
combination against the production meshes with ShapeDtypeStruct inputs —
no weight or activation is ever allocated. Produces the §Dry-run records
(memory analysis, FLOPs/bytes, collective schedule) that the roofline
analysis consumes.

Usage:
  python -m repro.launch.dryrun --arch gemma-7b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out experiments/dryrun]
"""
from repro.launch.mesh import forced_host_devices

forced_host_devices(512)   # BEFORE the jax backend initializes below

import argparse
import dataclasses
import json
import os
import re
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, all_configs, get_config
from repro.configs.base import ArchConfig, InputShape
from repro.launch import sharding as SH
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (make_decode_step, make_optimizer,
                                make_prefill_step, make_train_step,
                                opt_state_shapes)
from repro.models.model_zoo import build_model

LONG_CONTEXT_WINDOW = 4096   # sliding-window variant for dense archs @ 500k


def config_for(arch: str, shape: InputShape) -> ArchConfig:
    cfg = get_config(arch)
    if shape.name == "long_500k" and not cfg.subquadratic:
        # documented deviation (DESIGN.md §4): dense/MoE/VLM archs decode
        # 500k context only with the sliding-window attention variant
        cfg = dataclasses.replace(cfg, attn_window=LONG_CONTEXT_WINDOW)
    if shape.name == "long_500k" and cfg.family == "hybrid":
        # zamba2's shared attention block is windowed at 500k
        cfg = dataclasses.replace(cfg, attn_window=LONG_CONTEXT_WINDOW)
    return cfg


def _collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes of collective ops in post-SPMD HLO.

    Matches lines like:  %ag = bf16[8,128,...] all-gather(...)
    and accumulates the (shape) bytes per collective kind."""
    dtype_bytes = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                   "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                   "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1}
    kinds = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
    out = {k: 0 for k in kinds}
    counts = {k: 0 for k in kinds}
    pat = re.compile(
        r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\s("
        + "|".join(kinds) + r")(?:-start|-done)?\(")
    for m in pat.finditer(hlo_text):
        dt, dims, kind = m.groups()
        if dt not in dtype_bytes:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out[kind] += n * dtype_bytes[dt]
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total_bytes": sum(out.values()),
            "total_count": sum(counts.values())}


def _memory_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # CPU backend may not support it
        return {"error": str(e)}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "host_argument_size_in_bytes",
                  "host_output_size_in_bytes", "host_temp_size_in_bytes",
                  "serialized_size_in_bytes"):
        try:
            out[field] = int(getattr(ma, field))
        except Exception:
            pass
    return out


def _cost_analysis_dict(compiled) -> Dict[str, Any]:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in dict(ca).items()
            if isinstance(v, (int, float))}


def dryrun_pair(arch: str, shape_name: str, multi_pod: bool = False,
                collect_hlo: bool = True, lower_only: bool = False,
                microbatches: int = 1, fsdp_only: tuple = (),
                batch_both_axes: bool = False, embed_single_axis: bool = False,
                ssd_chunk: int = 0, shard_ssm_heads: bool = False,
                params_bf16: bool = False, shard_attn_heads: bool = False,
                variant: str = "") -> Dict[str, Any]:
    """Policy knobs (the §Perf levers):
      microbatches    — gradient accumulation in the train step;
      fsdp_only       — container names whose params skip 'model' sharding;
      batch_both_axes — shard the batch over data×model (pure DP), for
                        replicated-param policies.
    """
    shape = INPUT_SHAPES[shape_name]
    cfg = config_for(arch, shape)
    if ssd_chunk and cfg.ssm is not None:
        cfg = dataclasses.replace(cfg, ssm=dataclasses.replace(cfg.ssm,
                                                               chunk=ssd_chunk))
    if shard_ssm_heads:
        cfg = dataclasses.replace(cfg, shard_ssm_heads=True)
    if shard_attn_heads:
        cfg = dataclasses.replace(cfg, shard_attn_heads=True)
    model = build_model(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "multi_pod": multi_pod, "kind": shape.kind,
        "attn_window": cfg.attn_window, "variant": variant,
        "policy": {"microbatches": microbatches,
                   "fsdp_only": list(fsdp_only),
                   "batch_both_axes": batch_both_axes,
                   "embed_single_axis": embed_single_axis},
    }
    t0 = time.time()

    param_shapes = model.param_shapes()
    if params_bf16:
        param_shapes = jax.tree_util.tree_map(
            lambda sd: jax.ShapeDtypeStruct(sd.shape, jnp.bfloat16)
            if sd.dtype == jnp.float32 else sd, param_shapes)
    import math
    n_params = sum(math.prod(s.shape)
                   for s in jax.tree_util.tree_leaves(param_shapes))
    rec["num_params"] = n_params
    param_sh = SH.shard_params(param_shapes, mesh, fsdp_only_paths=fsdp_only,
                               embed_single_axis=embed_single_axis)

    def _batch_shard(specs):
        if not batch_both_axes:
            return SH.shard_batch(specs, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        axes = ("pod", "data", "model") if multi_pod else ("data", "model")

        def one(leaf):
            if len(leaf.shape) and leaf.shape[0] % (
                    math.prod(mesh.devices.shape)) == 0:
                return NamedSharding(mesh, P(axes))
            return SH.shard_batch(leaf, mesh) if False else NamedSharding(
                mesh, SH.batch_spec(tuple(leaf.shape), mesh))
        return jax.tree_util.tree_map(one, specs)

    with mesh:
        if shape.kind == "train":
            batch_specs = SP.train_specs(cfg, shape)
            batch_sh = _batch_shard(batch_specs)
            tx = make_optimizer(cfg)
            opt_shapes = opt_state_shapes(tx, param_shapes)
            opt_sh = SH.shard_params(opt_shapes, mesh, fsdp_only_paths=fsdp_only,
                                     embed_single_axis=embed_single_axis)
            step = make_train_step(model, tx, num_microbatches=microbatches)
            jitted = jax.jit(step,
                             in_shardings=(param_sh, opt_sh, batch_sh),
                             out_shardings=(param_sh, opt_sh, SH.replicated(mesh)),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(param_shapes, opt_shapes, batch_specs)
        elif shape.kind == "prefill":
            batch_specs = SP.prefill_specs(cfg, shape)
            batch_sh = _batch_shard(batch_specs)
            step = make_prefill_step(model)
            jitted = jax.jit(step, in_shardings=(param_sh, batch_sh))
            lowered = jitted.lower(param_shapes, batch_specs)
        else:  # decode
            batch_specs = SP.decode_specs(cfg, shape)
            batch_sh = _batch_shard(batch_specs)
            cache_shapes = model.cache_shapes(shape.global_batch, shape.seq_len)
            cache_sh = SH.shard_cache(cache_shapes, mesh)
            step = make_decode_step(model)
            jitted = jax.jit(step,
                             in_shardings=(param_sh, cache_sh, batch_sh),
                             out_shardings=(SH.replicated(mesh), cache_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(param_shapes, cache_shapes, batch_specs)
        rec["lower_s"] = round(time.time() - t0, 2)
        if lower_only:
            return rec
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    rec["memory_analysis"] = _memory_analysis_dict(compiled)
    rec["cost_analysis"] = _cost_analysis_dict(compiled)
    if collect_hlo:
        try:
            from repro.roofline.hlo_analysis import analyze_hlo_text
            hlo = compiled.as_text()
            rec["hlo_analysis"] = analyze_hlo_text(hlo).as_dict()
            rec["collectives"] = _collective_bytes(hlo)     # cross-check (uncorrected)
            rec["hlo_bytes_len"] = len(hlo)
            del hlo
        except Exception as e:
            rec["hlo_analysis"] = {"error": str(e)}
    from repro.roofline.analysis import model_flops, roofline_terms
    try:
        mf = model_flops(cfg, shape)
        rec["model_flops_global"] = mf
        ha = rec.get("hlo_analysis", {})
        if "dot_flops" in ha:
            n_dev = int(np_prod(mesh.devices.shape))
            rec["roofline"] = roofline_terms({
                "dot_flops": ha["dot_flops"],
                "traffic_bytes": ha["traffic_bytes"],
                "collective_bytes": ha["total_collective_bytes"],
            })
            rec["roofline"]["model_flops_per_device"] = mf / n_dev
            rec["roofline"]["useful_flops_ratio"] = (
                (mf / n_dev) / ha["dot_flops"] if ha["dot_flops"] else None)
    except Exception as e:
        rec["roofline"] = {"error": str(e)}
    rec["total_s"] = round(time.time() - t0, 2)
    return rec


def np_prod(t):
    out = 1
    for v in t:
        out *= int(v)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", type=str, default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--fsdp-only", nargs="*", default=[],
                    help="container names to shard data-only (e.g. blocks super rest)")
    ap.add_argument("--batch-both-axes", action="store_true")
    ap.add_argument("--embed-single-axis", action="store_true")
    ap.add_argument("--ssd-chunk", type=int, default=0)
    ap.add_argument("--shard-ssm-heads", action="store_true")
    ap.add_argument("--params-bf16", action="store_true")
    ap.add_argument("--shard-attn-heads", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf policy bundle per arch: head-dim "
                         "sharding constraints (attn + SSM), vocab-only "
                         "embedding sharding, input-dim FSDP for SSM blocks, "
                         "8 training microbatches, bf16 params")
    ap.add_argument("--variant", type=str, default="")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    pairs = []
    archs = list(all_configs()) if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    n_ok = 0
    for a, s, mp in pairs:
        tag = f"{a}__{s}__{'2x16x16' if mp else '16x16'}"
        if args.variant:
            tag += f"__{args.variant}"
        path = os.path.join(args.out, tag + ".json")
        if args.skip_existing and os.path.exists(path):
            print(f"[skip] {tag}")
            n_ok += 1
            continue
        print(f"[dryrun] {tag} ...", flush=True)
        kw = dict(microbatches=args.microbatches,
                  fsdp_only=tuple(args.fsdp_only),
                  batch_both_axes=args.batch_both_axes,
                  embed_single_axis=args.embed_single_axis,
                  ssd_chunk=args.ssd_chunk,
                  shard_ssm_heads=args.shard_ssm_heads,
                  params_bf16=args.params_bf16,
                  shard_attn_heads=args.shard_attn_heads)
        if args.optimized:
            fam = get_config(a).family
            kw.update(embed_single_axis=True, params_bf16=True,
                      shard_attn_heads=True)
            if fam in ("ssm", "hybrid"):
                kw.update(shard_ssm_heads=True,
                          fsdp_only=("blocks", "super", "rest"))
            if INPUT_SHAPES[s].kind == "train":
                kw.update(microbatches=8)
        try:
            rec = dryrun_pair(a, s, multi_pod=mp, variant=args.variant, **kw)
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            ca = rec.get("cost_analysis", {})
            print(f"  ok lower={rec.get('lower_s')}s compile={rec.get('compile_s')}s "
                  f"flops={ca.get('flops', 0):.3e} "
                  f"coll={rec.get('collectives', {}).get('total_bytes', 0):.3e}B",
                  flush=True)
            n_ok += 1
        except Exception as e:
            traceback.print_exc()
            with open(path + ".err", "w") as f:
                f.write(traceback.format_exc())
            print(f"  FAIL {type(e).__name__}: {e}", flush=True)
    print(f"{n_ok}/{len(pairs)} combinations lowered+compiled")


if __name__ == "__main__":
    main()
