"""Production mesh construction (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (batch/fsdp) × ``model`` (tensor/expert). The multi-pod
    mesh adds a leading ``pod`` axis — in the VFL mapping each pod is one
    party (DESIGN.md §3), and only the one-shot protocol's rep/grad
    exchanges cross it.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices_per_axis=(2, 2)):
    """Small host mesh for CI-sized sharding tests."""
    axes = ("data", "model") if len(devices_per_axis) == 2 else ("pod", "data", "model")
    return jax.make_mesh(tuple(devices_per_axis), axes)
