"""Production mesh construction (functions only — importing this module never
touches jax device state)."""
from __future__ import annotations

import os
import re

import jax

BATCH_AXIS = "batch"


def forced_host_devices(count: int) -> None:
    """Force the CPU backend to expose ``count`` host devices.

    Idempotent XLA_FLAGS edit: replaces any existing
    ``--xla_force_host_platform_device_count`` value rather than appending a
    second one. Only effective if called before the CPU backend initializes
    (i.e. before the first jax array/device query in the process).
    """
    flag = f"--xla_force_host_platform_device_count={int(count)}"
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" in flags:
        flags = re.sub(r"--xla_force_host_platform_device_count=\d+",
                       flag, flags)
    else:
        flags = (flags + " " + flag).strip()
    os.environ["XLA_FLAGS"] = flags


def make_batch_mesh(num_devices: int | None = None):
    """1-D mesh over the engine's anonymous stacked batch axis (DESIGN.md
    §14). ``None`` takes every visible device."""
    n = jax.device_count() if num_devices is None else int(num_devices)
    if n > jax.device_count():
        raise ValueError(
            f"requested a {n}-device batch mesh but only "
            f"{jax.device_count()} device(s) are visible — on CPU, call "
            "repro.launch.mesh.forced_host_devices before jax initializes")
    return jax.make_mesh((n,), (BATCH_AXIS,))


def make_production_mesh(*, multi_pod: bool = False):
    """v5e pod meshes: 16×16 = 256 chips per pod; 2 pods = 512 chips.

    Axes: ``data`` (batch/fsdp) × ``model`` (tensor/expert). The multi-pod
    mesh adds a leading ``pod`` axis — in the VFL mapping each pod is one
    party (DESIGN.md §3), and only the one-shot protocol's rep/grad
    exchanges cross it.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(devices_per_axis=(2, 2)):
    """Small host mesh for CI-sized sharding tests."""
    axes = ("data", "model") if len(devices_per_axis) == 2 else ("pod", "data", "model")
    return jax.make_mesh(tuple(devices_per_axis), axes)
