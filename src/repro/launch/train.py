"""Single-host training driver for the assigned architectures.

Trains a (reduced or full) config on synthetic token streams — the e2e
demonstration path for the model zoo substrate. On a real TPU slice the same
script runs under the production mesh (--mesh data,model).

  PYTHONPATH=src python -m repro.launch.train --arch mamba2-370m --reduce \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.data.synthetic import make_token_stream
from repro.launch.steps import make_optimizer, make_train_step
from repro.models.model_zoo import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default="mamba2-370m")
    ap.add_argument("--reduce", action="store_true",
                    help="use the CPU-sized reduced config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=str, default="")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduce:
        cfg = cfg.reduced()
    model = build_model(cfg)
    key = jax.random.PRNGKey(0)
    key, kp = jax.random.split(key)
    params = model.init(kp)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M")

    tx = make_optimizer(cfg, args.lr)
    opt_state = tx.init(params)
    step_fn = jax.jit(make_train_step(model, tx), donate_argnums=(0, 1))

    t0 = time.time()
    for step in range(args.steps):
        key, kd = jax.random.split(key)
        tokens, labels = make_token_stream(kd, args.batch, args.seq, cfg.vocab_size)
        batch = {"tokens": tokens, "labels": labels}
        if cfg.family in ("vlm", "audio"):
            key, ke = jax.random.split(key)
            batch["embeds"] = 0.02 * jax.random.normal(
                ke, (args.batch, cfg.prefix_tokens, cfg.d_model), jnp.bfloat16)
        params, opt_state, loss = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            print(f"step {step:5d} loss {float(loss):.4f} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
    if args.ckpt_dir:
        path = save_checkpoint(args.ckpt_dir, args.steps, params,
                               {"arch": cfg.name, "loss": float(loss)})
        print(f"saved {path}")


if __name__ == "__main__":
    main()
