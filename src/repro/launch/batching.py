"""The shared fixed-shape serving batcher (DESIGN.md §13).

Online traffic is ragged — requests arrive in dribbles of 1..capacity rows —
but jitted programs want ONE shape. The PR 3 training-side answer (pad to a
fixed gate count, carry a validity mask, let ``jnp.where`` neutralize the
padding) applies unchanged at serving time: every batch is padded to the
engine's ``capacity`` and travels with a boolean row mask, so one compiled
forward serves every traffic pattern and changing batch composition never
recompiles. Both serving drivers — the VFL path (``launch/vfl_serve``) and
the model-zoo path (``launch/serve``) — batch and time through this module
instead of forking their own loops.
"""
from __future__ import annotations

import time
from typing import Callable, List, NamedTuple, Sequence, Tuple

import jax.numpy as jnp
import numpy as np


class MaskedBatch(NamedTuple):
    """One fixed-shape unit of traffic: per-party feature blocks padded to
    capacity on axis 0, plus the validity mask separating real rows from
    padding."""

    xs: Tuple[jnp.ndarray, ...]     # K arrays, each (capacity, ...)
    mask: jnp.ndarray               # (capacity,) bool — True = real row
    n: int                          # number of valid rows


def pad_to_capacity(xs: Sequence[jnp.ndarray], capacity: int) -> MaskedBatch:
    """Pad every per-party block of an ``n``-row request up to ``capacity``
    rows (zeros — the mask, not the values, carries validity)."""
    n = int(xs[0].shape[0])
    if n > capacity:
        raise ValueError(f"request of {n} rows exceeds capacity {capacity}; "
                         f"split it with chunk_requests first")
    for x in xs[1:]:
        if int(x.shape[0]) != n:
            raise ValueError("every party block must carry the same rows")
    padded = tuple(
        jnp.pad(x, [(0, capacity - n)] + [(0, 0)] * (x.ndim - 1))
        for x in xs)
    mask = jnp.arange(capacity) < n
    return MaskedBatch(padded, mask, n)


def chunk_requests(xs: Sequence[jnp.ndarray],
                   capacity: int) -> List[Tuple[jnp.ndarray, ...]]:
    """Split an arbitrarily large request into capacity-sized chunks (the
    last one short — ``pad_to_capacity`` squares it up)."""
    n = int(xs[0].shape[0])
    return [tuple(x[i:i + capacity] for x in xs)
            for i in range(0, max(n, 1), capacity)]


def percentile(samples: Sequence[float], q: float) -> float:
    """The ``q``-th percentile (linear interpolation, numpy semantics)."""
    return float(np.percentile(np.asarray(samples, np.float64), q))


class LatencyRecorder:
    """Wall-clock samples → the serving row's p50/p99/throughput summary."""

    def __init__(self) -> None:
        self.samples_s: List[float] = []
        self.rows = 0

    def record(self, seconds: float, rows: int) -> None:
        self.samples_s.append(float(seconds))
        self.rows += int(rows)

    def summary(self) -> dict:
        if not self.samples_s:
            raise ValueError("no latency samples recorded")
        total = sum(self.samples_s)
        return {
            "batches": len(self.samples_s),
            "rows": self.rows,
            "p50_ms": percentile(self.samples_s, 50) * 1e3,
            "p99_ms": percentile(self.samples_s, 99) * 1e3,
            "mean_ms": total / len(self.samples_s) * 1e3,
            "rows_per_s": self.rows / total if total > 0 else float("inf"),
        }


def drive(step: Callable[[MaskedBatch], jnp.ndarray],
          requests: Sequence[Sequence[jnp.ndarray]],
          capacity: int,
          warmup: int = 1) -> Tuple[List[jnp.ndarray], LatencyRecorder]:
    """Run a request stream through a fixed-shape step: chunk → pad → call,
    timing each step after ``warmup`` untimed compile calls. ``step`` takes
    a :class:`MaskedBatch` and returns per-row outputs (capacity leading);
    only the valid rows are kept. Returns (per-request outputs, recorder).
    """
    rec = LatencyRecorder()
    if requests and warmup > 0:
        for _ in range(warmup):
            # a fresh padded batch per call: steps may donate their inputs
            first = pad_to_capacity(chunk_requests(requests[0], capacity)[0],
                                    capacity)
            step(first).block_until_ready()
    outs: List[jnp.ndarray] = []
    for req in requests:
        parts = []
        for chunk in chunk_requests(req, capacity):
            batch = pad_to_capacity(chunk, capacity)
            t0 = time.perf_counter()
            out = step(batch)
            out.block_until_ready()
            rec.record(time.perf_counter() - t0, batch.n)
            parts.append(out[:batch.n])
        outs.append(jnp.concatenate(parts, axis=0) if len(parts) > 1
                    else parts[0])
    return outs, rec
