from repro.models.extractors import (
    Model,
    make_classifier,
    make_cnn_extractor,
    make_mlp_extractor,
)

__all__ = ["Model", "make_classifier", "make_cnn_extractor", "make_mlp_extractor"]
