"""Use any assigned architecture as a VFL representation extractor f_k.

The vertical split for sequence data gives each party a token-range slice
(DESIGN.md §4); the party's backbone encodes its slice and mean-pools the
final hidden states into a rep_dim representation. This is what "the paper's
technique applied to the assigned architectures" means operationally: the
one-shot/few-shot protocol (gradient clustering, SSL with the tabular
FixMatch-tab masking over embeddings) runs unchanged on top.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.extractors import Model
from repro.models.model_zoo import build_model


def make_zoo_extractor(cfg: ArchConfig, rep_dim: int = 64) -> Model:
    """Model facade over a (reduced) zoo backbone: x is (B, S) int32 tokens."""
    backbone = build_model(cfg)

    def init(key, sample):
        k1, k2 = jax.random.split(key)
        params = backbone.init(k1)
        params["rep_head"] = (0.02 * jax.random.normal(
            k2, (cfg.d_model, rep_dim))).astype(jnp.float32)
        return params

    def apply(params, x, train: bool = False):
        del train
        body = {k: v for k, v in params.items() if k != "rep_head"}
        h = backbone.hidden_fn(body, {"tokens": x.astype(jnp.int32)})
        pooled = jnp.mean(h.astype(jnp.float32), axis=1)
        return pooled @ params["rep_head"]

    return Model(init=init, apply=apply, rep_dim=rep_dim)
