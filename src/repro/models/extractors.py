"""Per-party representation extractors f_k and server classifiers f_c.

Functional API used throughout the repo:

    model = make_cnn_extractor(rep_dim=128)
    params = model.init(key, sample_input)
    reps   = model.apply(params, x, train=True)

The image extractor is a WideResNet-style residual CNN (GroupNorm instead of
BatchNorm so the model stays a pure function of (params, x) — no mutable
running statistics; this is the standard TPU/functional adaptation and noted
in DESIGN.md §7). The paper uses WideResNet20; depth/width are configurable
and the default matches that scale class on half-images.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Sequence

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class Model:
    init: Callable[..., Any]
    apply: Callable[..., jnp.ndarray]
    rep_dim: int


# ---------------------------------------------------------------- helpers --
def _he(key, shape, fan_in):
    return jax.random.normal(key, shape, jnp.float32) * jnp.sqrt(2.0 / fan_in)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _group_norm(x, scale, bias, groups=8, eps=1e-5):
    n, h, w, c = x.shape
    g = math.gcd(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mean = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + eps)
    return xg.reshape(n, h, w, c) * scale + bias


# ---------------------------------------------------------- CNN extractor --
def make_cnn_extractor(rep_dim: int = 128, widths: Sequence[int] = (32, 64, 128),
                       blocks_per_stage: int = 2) -> Model:
    """WideResNet-style residual CNN for (N, H, W, C) inputs."""

    def init(key, sample):
        c_in = sample.shape[-1]
        params: Dict[str, Any] = {}
        key, k0 = jax.random.split(key)
        params["stem"] = _he(k0, (3, 3, c_in, widths[0]), 9 * c_in)
        prev = widths[0]
        for s, width in enumerate(widths):
            for b in range(blocks_per_stage):
                key, k1, k2, k3 = jax.random.split(key, 4)
                pfx = f"s{s}b{b}"
                params[pfx] = {
                    "conv1": _he(k1, (3, 3, prev, width), 9 * prev),
                    "conv2": _he(k2, (3, 3, width, width), 9 * width),
                    "gn1_scale": jnp.ones((prev,)), "gn1_bias": jnp.zeros((prev,)),
                    "gn2_scale": jnp.ones((width,)), "gn2_bias": jnp.zeros((width,)),
                }
                if prev != width:
                    params[pfx]["proj"] = _he(k3, (1, 1, prev, width), prev)
                prev = width
        key, kh = jax.random.split(key)
        params["head_w"] = _he(kh, (prev, rep_dim), prev)
        params["head_b"] = jnp.zeros((rep_dim,))
        params["out_gn_scale"] = jnp.ones((prev,))
        params["out_gn_bias"] = jnp.zeros((prev,))
        return params

    def apply(params, x, train: bool = False):
        del train  # no dropout/BN state — augmentation happens in the data path
        h = _conv(x, params["stem"])
        for s in range(len(widths)):
            for b in range(blocks_per_stage):
                p = params[f"s{s}b{b}"]
                stride = 2 if (b == 0 and s > 0) else 1
                y = _group_norm(h, p["gn1_scale"], p["gn1_bias"])
                y = jax.nn.relu(y)
                shortcut = h
                if "proj" in p:
                    shortcut = _conv(y, p["proj"], stride=stride)
                elif stride != 1:
                    shortcut = h[:, ::stride, ::stride, :]
                y = _conv(y, p["conv1"], stride=stride)
                y = _group_norm(y, p["gn2_scale"], p["gn2_bias"])
                y = jax.nn.relu(y)
                y = _conv(y, p["conv2"])
                h = shortcut + y
        h = jax.nn.relu(_group_norm(h, params["out_gn_scale"], params["out_gn_bias"]))
        h = h.mean(axis=(1, 2))  # global average pool
        return h @ params["head_w"] + params["head_b"]

    return Model(init=init, apply=apply, rep_dim=rep_dim)


# ---------------------------------------------------------- MLP extractor --
def make_mlp_extractor(rep_dim: int = 64, hidden: Sequence[int] = (128, 128)) -> Model:
    """Two-layer-style MLP for tabular parties (the paper's credit model)."""

    dims_hidden = tuple(hidden)

    def init(key, sample):
        d = sample.shape[-1]
        dims = (d,) + dims_hidden + (rep_dim,)
        params = {}
        for i in range(len(dims) - 1):
            key, k = jax.random.split(key)
            params[f"w{i}"] = _he(k, (dims[i], dims[i + 1]), dims[i])
            params[f"b{i}"] = jnp.zeros((dims[i + 1],))
        return params

    def apply(params, x, train: bool = False):
        del train
        n_layers = len([k for k in params if k.startswith("w")])
        h = x
        for i in range(n_layers):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    return Model(init=init, apply=apply, rep_dim=rep_dim)


# --------------------------------------------------------- server classifier
def make_classifier(num_classes: int, hidden: Sequence[int] = ()) -> Model:
    """Server-side f_c over concatenated representations (linear by default,
    matching SplitNN-style heads; optional MLP)."""

    dims_hidden = tuple(hidden)

    def init(key, sample):
        d = sample.shape[-1]
        dims = (d,) + dims_hidden + (num_classes,)
        params = {}
        for i in range(len(dims) - 1):
            key, k = jax.random.split(key)
            params[f"w{i}"] = _he(k, (dims[i], dims[i + 1]), dims[i])
            params[f"b{i}"] = jnp.zeros((dims[i + 1],))
        return params

    def apply(params, x, train: bool = False):
        del train
        n_layers = len([k for k in params if k.startswith("w")])
        h = x
        for i in range(n_layers):
            h = h @ params[f"w{i}"] + params[f"b{i}"]
            if i < n_layers - 1:
                h = jax.nn.relu(h)
        return h

    return Model(init=init, apply=apply, rep_dim=num_classes)
