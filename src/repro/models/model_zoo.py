"""Assemble complete models from an ArchConfig.

A ``ModelDef`` exposes exactly what the launchers need:
  * ``param_shapes()``  — ShapeDtypeStruct tree (dry-run lowers from this);
  * ``init(key)``       — materialized params (smoke tests / examples);
  * ``loss_fn``         — next-token CE over a (tokens, labels) batch;
  * ``prefill_fn``      — full-sequence forward → last-position logits;
  * ``decode_fn``       — one token against a KV/SSM cache;
  * ``cache_shapes``    — the decode cache tree for a (batch, cache_len).

Repeated layers are stacked along a leading L axis and driven by
``jax.lax.scan`` so that HLO size is O(1) in depth (compile-time at 126
layers would otherwise be prohibitive) and remat policy applies per block.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM
from repro.models.layers import Shapes, sds


def _stack_shapes(shapes: Shapes, n: int) -> Shapes:
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct((n,) + tuple(s.shape), s.dtype), shapes)


def _act_dtype(cfg: ArchConfig):
    return jnp.bfloat16 if cfg.activation_dtype == "bfloat16" else jnp.float32


# ===========================================================================
# Block definitions
# ===========================================================================
def _dense_block_shapes(cfg: ArchConfig, use_moe: bool, d_ff: Optional[int] = None
                        ) -> Shapes:
    s: Shapes = {"ln1_scale": sds(cfg.d_model), "ln2_scale": sds(cfg.d_model)}
    if cfg.mla is not None:
        s["attn"] = L.mla_shapes(cfg)
    else:
        s["attn"] = L.attention_shapes(cfg)
    if use_moe:
        s["moe"] = MOE.moe_shapes(cfg)
    else:
        s["ffn"] = L.ffn_shapes(cfg, d_ff=d_ff)
    return s


def _dense_block_apply(params, x, cfg: ArchConfig, positions, positions3,
                       window, cache, use_moe: bool):
    attn_in = L.rms_norm(x, params["ln1_scale"], cfg.norm_eps)
    if cfg.mla is not None:
        h, new_cache = L.mla_apply(params["attn"], attn_in, cfg, positions,
                                   window=window, cache=cache)
    else:
        h, new_cache = L.attention_apply(params["attn"], attn_in, cfg, positions,
                                         positions3=positions3, window=window,
                                         cache=cache)
    x = x + h.astype(x.dtype)
    ff_in = L.rms_norm(x, params["ln2_scale"], cfg.norm_eps)
    if use_moe:
        y, aux = MOE.moe_apply(params["moe"], ff_in, cfg)
    else:
        y, aux = L.ffn_apply(params["ffn"], ff_in, cfg), jnp.zeros((), jnp.float32)
    return x + y.astype(x.dtype), new_cache, aux


def _mamba_block_shapes(cfg: ArchConfig) -> Shapes:
    return {"ln_scale": sds(cfg.d_model), "mamba": SSM.mamba_shapes(cfg)}


def _mamba_block_apply(params, x, cfg: ArchConfig, cache):
    h, new_cache = SSM.mamba_apply(params["mamba"],
                                   L.rms_norm(x, params["ln_scale"], cfg.norm_eps),
                                   cfg, cache=cache)
    return x + h.astype(x.dtype), new_cache


# ===========================================================================
# Decoder-only stack (dense / moe / vlm)
# ===========================================================================
def _decoder_shapes(cfg: ArchConfig) -> Shapes:
    s: Shapes = {"embed": L.embedding_shapes(cfg),
                 "final_ln_scale": sds(cfg.d_model)}
    if cfg.family == "moe":
        n_moe = cfg.num_layers - (1 if cfg.mla is not None else 0)
        if cfg.mla is not None:   # deepseek: first layer dense
            s["dense0"] = _dense_block_shapes(cfg, use_moe=False, d_ff=cfg.d_ff)
        s["blocks"] = _stack_shapes(_dense_block_shapes(cfg, use_moe=True), n_moe)
    else:
        s["blocks"] = _stack_shapes(_dense_block_shapes(cfg, use_moe=False),
                                    cfg.num_layers)
    return s


def _positions3_for(cfg: ArchConfig, batch: int, prefix: int, total: int,
                    offset) -> jnp.ndarray:
    """M-RoPE position streams (3, B, S): patch prefix gets a (t=0, h, w)
    grid; text gets t=h=w=linear position."""
    side = max(int(math.sqrt(max(prefix, 1))), 1)
    idx = jnp.arange(total)
    is_text = idx >= prefix
    t = jnp.where(is_text, idx, 0)
    hh = jnp.where(is_text, idx, idx // side)
    ww = jnp.where(is_text, idx, idx % side)
    pos3 = jnp.stack([t, hh, ww])[:, None, :] + jnp.zeros((1, batch, 1), jnp.int32)
    return pos3 + offset[None, :, None] if offset is not None else pos3


def _decoder_forward(params, cfg: ArchConfig, x, positions, positions3,
                     window, caches):
    """x: (B, S, d) embedded input. caches: None (train/prefill) or stacked
    tree. Returns (hidden, new_caches, aux_loss_sum)."""
    decode = caches is not None
    use_moe = cfg.family == "moe"

    def block(p, x, cache):
        return _dense_block_apply(p, x, cfg, positions, positions3, window,
                                  cache, use_moe=use_moe)

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.family == "moe" and cfg.mla is not None:
        c0 = caches["dense0"] if decode else None
        x, nc0, _ = _dense_block_apply(params["dense0"], x, cfg, positions,
                                       positions3, window, c0, use_moe=False)
    else:
        nc0 = None

    def scan_fn(carry, inp):
        x, aux = carry
        if decode:
            p, c = inp
        else:
            p, c = inp, None
        x, nc, a = block(p, x, c)
        return (x, aux + a), nc

    scan_body = jax.checkpoint(scan_fn) if (cfg.remat and not decode) else scan_fn
    xs = (params["blocks"], caches["blocks"]) if decode else params["blocks"]
    (x, aux_total), new_block_caches = jax.lax.scan(scan_body, (x, aux_total), xs)

    x = L.rms_norm(x, params["final_ln_scale"], cfg.norm_eps)
    new_caches = None
    if decode:
        new_caches = {"blocks": new_block_caches}
        if nc0 is not None:
            new_caches["dense0"] = nc0
    return x, new_caches, aux_total


# ===========================================================================
# Hybrid (zamba2): mamba backbone + one SHARED attention block
# ===========================================================================
def _hybrid_shapes(cfg: ArchConfig) -> Shapes:
    n_super = cfg.num_layers // cfg.hybrid_attn_every
    n_rest = cfg.num_layers - n_super * cfg.hybrid_attn_every
    s: Shapes = {
        "embed": L.embedding_shapes(cfg),
        "final_ln_scale": sds(cfg.d_model),
        "shared_attn": _dense_block_shapes(cfg, use_moe=False),
        "super": _stack_shapes(
            _stack_shapes(_mamba_block_shapes(cfg), cfg.hybrid_attn_every), n_super),
    }
    if n_rest:
        s["rest"] = _stack_shapes(_mamba_block_shapes(cfg), n_rest)
    return s


def _hybrid_forward(params, cfg: ArchConfig, x, positions, window, caches):
    decode = caches is not None
    n_super = cfg.num_layers // cfg.hybrid_attn_every

    def mamba_scan(x, stacked, stacked_cache):
        def fn(carry, inp):
            if decode:
                p, c = inp
            else:
                p, c = inp, None
            h, nc = _mamba_block_apply(p, carry, cfg, c)
            return h, nc
        body = jax.checkpoint(fn) if (cfg.remat and not decode) else fn
        xs = (stacked, stacked_cache) if decode else stacked
        return jax.lax.scan(body, x, xs)

    def super_fn(carry, inp):
        x = carry
        if decode:
            p, c = inp
            x, new_mcache = mamba_scan(x, p, c["mamba"])
            x, new_acache, _ = _dense_block_apply(
                params["shared_attn"], x, cfg, positions, None, window,
                c["attn"], use_moe=False)
            return x, {"mamba": new_mcache, "attn": new_acache}
        p = inp
        x, _ = mamba_scan(x, p, None)
        x, _, _ = _dense_block_apply(params["shared_attn"], x, cfg, positions,
                                     None, window, None, use_moe=False)
        return x, None

    xs = (params["super"], caches["super"]) if decode else params["super"]
    x, new_super = jax.lax.scan(super_fn, x, xs)

    new_rest = None
    if "rest" in params:
        x, new_rest = mamba_scan(x, params["rest"],
                                 caches["rest"] if decode else None)

    x = L.rms_norm(x, params["final_ln_scale"], cfg.norm_eps)
    new_caches = None
    if decode:
        new_caches = {"super": new_super}
        if new_rest is not None:
            new_caches["rest"] = new_rest
    return x, new_caches


# ===========================================================================
# SSM (mamba2): pure mamba stack
# ===========================================================================
def _ssm_shapes(cfg: ArchConfig) -> Shapes:
    return {
        "embed": L.embedding_shapes(cfg),
        "final_ln_scale": sds(cfg.d_model),
        "blocks": _stack_shapes(_mamba_block_shapes(cfg), cfg.num_layers),
    }


def _ssm_forward(params, cfg: ArchConfig, x, caches):
    decode = caches is not None

    def fn(carry, inp):
        if decode:
            p, c = inp
        else:
            p, c = inp, None
        h, nc = _mamba_block_apply(p, carry, cfg, c)
        return h, nc

    body = jax.checkpoint(fn) if (cfg.remat and not decode) else fn
    xs = (params["blocks"], caches["blocks"]) if decode else params["blocks"]
    x, new_caches = jax.lax.scan(body, x, xs)
    x = L.rms_norm(x, params["final_ln_scale"], cfg.norm_eps)
    return x, ({"blocks": new_caches} if decode else None)


# ===========================================================================
# Encoder-decoder (seamless)
# ===========================================================================
def _enc_block_shapes(cfg: ArchConfig) -> Shapes:
    return {"ln1_scale": sds(cfg.d_model), "ln2_scale": sds(cfg.d_model),
            "attn": L.attention_shapes(cfg), "ffn": L.ffn_shapes(cfg)}


def _dec_block_shapes(cfg: ArchConfig) -> Shapes:
    return {"ln1_scale": sds(cfg.d_model), "ln2_scale": sds(cfg.d_model),
            "ln3_scale": sds(cfg.d_model),
            "self_attn": L.attention_shapes(cfg),
            "cross_attn": L.attention_shapes(cfg),
            "ffn": L.ffn_shapes(cfg)}


def _encdec_shapes(cfg: ArchConfig) -> Shapes:
    return {
        "embed": L.embedding_shapes(cfg),
        "final_ln_scale": sds(cfg.d_model),
        "enc_final_ln_scale": sds(cfg.d_model),
        "enc_blocks": _stack_shapes(_enc_block_shapes(cfg), cfg.encoder_layers),
        "dec_blocks": _stack_shapes(_dec_block_shapes(cfg), cfg.num_layers),
    }


def _sinusoidal_pos(positions: jnp.ndarray, d: int) -> jnp.ndarray:
    """SeamlessM4T/NLLB-style sinusoidal position embeddings (computed, not
    learned — no table bound at long contexts). positions: any int shape."""
    half = d // 2
    freqs = jnp.exp(-jnp.arange(half, dtype=jnp.float32)
                    * (jnp.log(10000.0) / max(half - 1, 1)))
    ang = positions[..., None].astype(jnp.float32) * freqs
    emb = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
    if d % 2:
        emb = jnp.pad(emb, [(0, 0)] * (emb.ndim - 1) + [(0, 1)])
    return emb


def _encode(params, cfg: ArchConfig, embeds):
    b, s, _ = embeds.shape
    pos = jnp.arange(s)
    x = embeds.astype(_act_dtype(cfg)) \
        + _sinusoidal_pos(pos, cfg.d_model)[None].astype(_act_dtype(cfg))
    positions = jnp.broadcast_to(pos[None], (b, s))

    def fn(x, p):
        h, _ = L.attention_apply(p["attn"],
                                 L.rms_norm(x, p["ln1_scale"], cfg.norm_eps),
                                 cfg, positions, kv_chunk=min(1024, s))
        # non-causal: bidirectional self-attention
        x = x + h.astype(x.dtype)
        y = L.ffn_apply(p["ffn"], L.rms_norm(x, p["ln2_scale"], cfg.norm_eps), cfg)
        return x + y.astype(x.dtype), None

    # bidirectional: patch causal masking by passing positions that never mask
    def fn_bidir(x, p):
        attn_in = L.rms_norm(x, p["ln1_scale"], cfg.norm_eps)
        h, _ = L.attention_apply(
            p["attn"], attn_in, cfg,
            positions=jnp.zeros_like(positions),   # dpos==0 → causal mask all-pass
            kv_chunk=min(1024, s))
        x = x + h.astype(x.dtype)
        y = L.ffn_apply(p["ffn"], L.rms_norm(x, p["ln2_scale"], cfg.norm_eps), cfg)
        return x + y.astype(x.dtype), None

    body = jax.checkpoint(fn_bidir) if cfg.remat else fn_bidir
    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return L.rms_norm(x, params["enc_final_ln_scale"], cfg.norm_eps)


def _decode_stack(params, cfg: ArchConfig, x, positions, enc_out, window, caches):
    decode = caches is not None
    b = x.shape[0]

    def fn(carry, inp):
        x = carry
        if decode:
            p, c = inp
        else:
            p, c = inp, None
        h, nc = L.attention_apply(p["self_attn"],
                                  L.rms_norm(x, p["ln1_scale"], cfg.norm_eps),
                                  cfg, positions, window=window, cache=c)
        x = x + h.astype(x.dtype)
        ck = L.rms_norm(x, p["ln2_scale"], cfg.norm_eps)
        # cross-attention K/V from encoder output (recomputed per block from
        # the block's own projections)
        kv_in = enc_out
        k = (kv_in @ p["cross_attn"]["w_k"]).reshape(
            b, kv_in.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        v = (kv_in @ p["cross_attn"]["w_v"]).reshape(
            b, kv_in.shape[1], cfg.num_kv_heads, cfg.resolved_head_dim)
        h2, _ = L.attention_apply(p["cross_attn"], ck, cfg, positions,
                                  cross_kv=(k, v))
        x = x + h2.astype(x.dtype)
        y = L.ffn_apply(p["ffn"], L.rms_norm(x, p["ln3_scale"], cfg.norm_eps), cfg)
        return x + y.astype(x.dtype), nc

    body = jax.checkpoint(fn) if (cfg.remat and not decode) else fn
    xs = (params["dec_blocks"], caches["blocks"]) if decode else params["dec_blocks"]
    x, new_caches = jax.lax.scan(body, x, xs)
    x = L.rms_norm(x, params["final_ln_scale"], cfg.norm_eps)
    return x, ({"blocks": new_caches} if decode else None)


# ===========================================================================
# ModelDef
# ===========================================================================
@dataclass(frozen=True)
class ModelDef:
    cfg: ArchConfig
    param_shapes: Callable[[], Shapes]
    init: Callable[[jax.Array], Any]
    loss_fn: Callable[[Any, Dict[str, jnp.ndarray]], jnp.ndarray]
    prefill_fn: Callable[[Any, Dict[str, jnp.ndarray]], jnp.ndarray]
    decode_fn: Callable[[Any, Any, Dict[str, jnp.ndarray]], Tuple[jnp.ndarray, Any]]
    cache_shapes: Callable[[int, int], Shapes]
    hidden_fn: Callable[[Any, Dict[str, jnp.ndarray]], jnp.ndarray] = None


def _ce_loss(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def build_model(cfg: ArchConfig, window_override: Optional[int] = None) -> ModelDef:
    """window_override: force sliding-window attention (the long_500k variant
    for dense archs — DESIGN.md §4)."""
    window = window_override if window_override is not None else cfg.attn_window
    adt = _act_dtype(cfg)

    # ----------------------------------------------------------- shapes ----
    if cfg.family in ("dense", "moe", "vlm"):
        shapes_fn = lambda: _decoder_shapes(cfg)
    elif cfg.family == "hybrid":
        shapes_fn = lambda: _hybrid_shapes(cfg)
    elif cfg.family == "ssm":
        shapes_fn = lambda: _ssm_shapes(cfg)
    elif cfg.family == "audio":
        shapes_fn = lambda: _encdec_shapes(cfg)
    else:
        raise ValueError(cfg.family)

    # ----------------------------------------------------- forward pieces --
    def embed_batch(params, batch):
        """tokens (+ prefix embeds) → (x, positions, positions3)."""
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x = L.embed(params["embed"], tokens, cfg)
        prefix = 0
        if "embeds" in batch and cfg.family in ("vlm",):
            pre = batch["embeds"].astype(x.dtype)
            x = jnp.concatenate([pre, x], axis=1)
            prefix = pre.shape[1]
        s = x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        positions3 = None
        if cfg.rope_style == "mrope":
            positions3 = _positions3_for(cfg, b, prefix, s, None)
        return x, positions, positions3, prefix

    def forward_hidden(params, batch, caches=None, decode_positions=None):
        if cfg.family == "audio":
            enc_out = _encode(params, cfg, batch["embeds"])
            if caches is None:
                tokens = batch["tokens"]
                b, s = tokens.shape
                x = L.embed(params["embed"], tokens, cfg)
                x = x + _sinusoidal_pos(jnp.arange(s), cfg.d_model)[None].astype(x.dtype)
                positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
                h, _ = _decode_stack(params, cfg, x, positions, enc_out,
                                     window, None)
                return h, None, jnp.zeros((), jnp.float32)
            # decode: enc_out precomputed is in batch["embeds"]-derived cache?
            raise RuntimeError("audio decode uses forward_decode")
        if cfg.family in ("dense", "moe", "vlm"):
            x, positions, positions3, _ = embed_batch(params, batch)
            return _decoder_forward(params, cfg, x, positions, positions3,
                                    window, caches)
        if cfg.family == "hybrid":
            x, positions, _, _ = embed_batch(params, batch)
            h, nc = _hybrid_forward(params, cfg, x, positions, window, caches)
            return h, nc, jnp.zeros((), jnp.float32)
        if cfg.family == "ssm":
            x, _, _, _ = embed_batch(params, batch)
            h, nc = _ssm_forward(params, cfg, x, caches)
            return h, nc, jnp.zeros((), jnp.float32)
        raise ValueError(cfg.family)

    # -------------------------------------------------------------- loss ---
    def loss_fn(params, batch):
        h, _, aux = forward_hidden(params, batch)
        labels = batch["labels"]
        if cfg.family == "vlm" and "embeds" in batch:
            h = h[:, batch["embeds"].shape[1]:, :]   # loss over text positions
        logits = L.unembed(params["embed"], h, cfg)
        return _ce_loss(logits, labels) + 0.01 * aux

    # ------------------------------------------------------------ prefill --
    def prefill_fn(params, batch):
        h, _, _ = forward_hidden(params, batch)
        last = h[:, -1:, :]
        logits = L.unembed(params["embed"], last, cfg)
        return logits[:, 0, :]

    # ------------------------------------------------------------- decode --
    def decode_fn(params, caches, batch):
        token = batch["token"]                       # (B, 1)
        pos = batch["pos"]                           # (B, 1) int32
        b = token.shape[0]
        x = L.embed(params["embed"], token, cfg)
        if cfg.family == "audio":
            x = x + _sinusoidal_pos(pos[:, 0], cfg.d_model)[:, None].astype(x.dtype)
            enc_out = caches["enc_out"].astype(adt)
            h, nc = _decode_stack(params, cfg, x, pos, enc_out, window,
                                  {"blocks": caches["blocks"]})
            nc["enc_out"] = caches["enc_out"]
        elif cfg.family in ("dense", "moe", "vlm"):
            positions3 = None
            if cfg.rope_style == "mrope":
                positions3 = jnp.broadcast_to(pos[None], (3, b, 1))
            h, nc, _ = _decoder_forward(params, cfg, x, pos, positions3,
                                        window, caches)
        elif cfg.family == "hybrid":
            h, nc = _hybrid_forward(params, cfg, x, pos, window, caches)
        elif cfg.family == "ssm":
            h, nc = _ssm_forward(params, cfg, x, caches)
        else:
            raise ValueError(cfg.family)
        logits = L.unembed(params["embed"], h, cfg)[:, 0, :]
        return logits, nc

    # ------------------------------------------------------ cache shapes ---
    def cache_shapes(batch: int, cache_len: int) -> Shapes:
        eff_len = min(cache_len, window) if window is not None else cache_len
        if cfg.family in ("dense", "moe", "vlm"):
            if cfg.mla is not None:
                blk = L.mla_cache_shapes(cfg, batch, eff_len)
                n_moe = cfg.num_layers - 1
                out = {"blocks": _stack_shapes(blk, n_moe), "dense0": blk}
            else:
                blk = L.attention_cache_shapes(cfg, batch, eff_len)
                out = {"blocks": _stack_shapes(blk, cfg.num_layers)}
            return out
        if cfg.family == "hybrid":
            n_super = cfg.num_layers // cfg.hybrid_attn_every
            n_rest = cfg.num_layers - n_super * cfg.hybrid_attn_every
            attn_len = min(eff_len, cfg.attn_window or eff_len)
            super_blk = {
                "mamba": _stack_shapes(SSM.mamba_cache_shapes(cfg, batch),
                                       cfg.hybrid_attn_every),
                "attn": L.attention_cache_shapes(cfg, batch, attn_len),
            }
            out = {"super": _stack_shapes(super_blk, n_super)}
            if n_rest:
                out["rest"] = _stack_shapes(SSM.mamba_cache_shapes(cfg, batch),
                                            n_rest)
            return out
        if cfg.family == "ssm":
            return {"blocks": _stack_shapes(SSM.mamba_cache_shapes(cfg, batch),
                                            cfg.num_layers)}
        if cfg.family == "audio":
            blk = L.attention_cache_shapes(cfg, batch, eff_len)
            return {"blocks": _stack_shapes(blk, cfg.num_layers),
                    "enc_out": sds(batch, cfg.prefix_tokens, cfg.d_model,
                                   dtype=jnp.bfloat16)}
        raise ValueError(cfg.family)

    def init(key):
        return L.init_params(key, shapes_fn())

    def hidden_fn(params, batch):
        """Final-layer hidden states (B, S, d) — used when the backbone acts
        as a VFL representation extractor f_k (DESIGN.md §4)."""
        h, _, _ = forward_hidden(params, batch)
        return h

    return ModelDef(cfg=cfg, param_shapes=shapes_fn, init=init,
                    loss_fn=loss_fn, prefill_fn=prefill_fn,
                    decode_fn=decode_fn, cache_shapes=cache_shapes,
                    hidden_fn=hidden_fn)
