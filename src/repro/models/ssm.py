"""Mamba2 (SSD — state-space duality) blocks, chunked for TPU.

Training/prefill uses the chunked SSD algorithm [arXiv:2405.21060 §6]:
within-chunk terms are dense (L, L) matmuls that feed the MXU; cross-chunk
state is carried by a lax.scan over chunks — sequence-parallel-friendly and
never materializes the (S, S) semiseparable matrix.

Decode is the constant-memory recurrence: h ← exp(Δ·A)·h + Δ·B·x per step,
with a (conv_width-1)-deep rolling buffer for the causal conv.

Single SSM group (G=1), matching the assigned Mamba2/Zamba2 scales.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Shapes, rms_norm, sds


def _dims(cfg: ArchConfig):
    ssm = cfg.ssm
    d_inner = ssm.expand * cfg.d_model
    n_heads = d_inner // ssm.head_dim
    return d_inner, n_heads, ssm.d_state, ssm.conv_width


def mamba_shapes(cfg: ArchConfig) -> Shapes:
    d_inner, n_heads, n, width = _dims(cfg)
    d = cfg.d_model
    conv_ch = d_inner + 2 * n
    return {
        "in_proj": sds(d, 2 * d_inner + 2 * n + n_heads),
        "conv_w": sds(width, conv_ch),
        "conv_bias": sds(conv_ch),
        "A_log": sds(n_heads),
        "D": sds(n_heads),
        "dt_bias": sds(n_heads),
        "gate_norm_scale": sds(d_inner),
        "out_proj": sds(d_inner, d),
    }


def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """(..., L) → (..., L, L) lower-triangular segment sums Σ_{j<k≤i} x_k."""
    l = x.shape[-1]
    cum = jnp.cumsum(x, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]
    mask = jnp.tril(jnp.ones((l, l), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jnp.ndarray, dt: jnp.ndarray, a: jnp.ndarray,
                b_mat: jnp.ndarray, c_mat: jnp.ndarray, chunk: int,
                initial_state: Optional[jnp.ndarray] = None
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD scan. x (B,S,H,P), dt (B,S,H), a (H,) negative, b/c (B,S,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    bb, s, h, p = x.shape
    n = b_mat.shape[-1]
    nc = s // chunk
    assert nc * chunk == s, (s, chunk)
    xc = x.reshape(bb, nc, chunk, h, p)
    dtc = dt.reshape(bb, nc, chunk, h)
    bc = b_mat.reshape(bb, nc, chunk, n)
    cc = c_mat.reshape(bb, nc, chunk, n)

    a_bar = dtc * a[None, None, None, :]                      # (b,c,l,h)
    a_cum = jnp.cumsum(a_bar, axis=2)
    # within-chunk (the "quadratic attention-like" branch)
    decay = jnp.exp(_segsum(jnp.moveaxis(a_bar, -1, 2)))      # (b,c,h,l,l)
    cb = jnp.einsum("bcln,bcjn->bclj", cc, bc)                # (b,c,l,j)
    m = cb[:, :, None] * decay                                # (b,c,h,l,j)
    y_diag = jnp.einsum("bchlj,bcjh,bcjhp->bclhp", m, dtc, xc)

    # end-of-chunk states
    state_decay = jnp.exp(a_cum[:, :, -1:, :] - a_cum)        # (b,c,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", bc, state_decay * dtc, xc)

    # cross-chunk scan
    chunk_decay = jnp.exp(a_cum[:, :, -1, :])                 # (b,c,h)
    h0 = (initial_state if initial_state is not None
          else jnp.zeros((bb, h, p, n), jnp.float32))

    def scan_fn(carry, inp):
        st, dec = inp                                          # (b,h,p,n), (b,h)
        new = carry * dec[..., None, None] + st
        return new, carry                                      # emit PREVIOUS

    final, prev_states = jax.lax.scan(
        scan_fn, h0.astype(jnp.float32),
        (jnp.moveaxis(states, 1, 0).astype(jnp.float32),
         jnp.moveaxis(chunk_decay, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # (b,c,h,p,n)

    in_decay = jnp.exp(a_cum)                                  # (b,c,l,h)
    y_off = jnp.einsum("bcln,bchpn,bclh->bclhp", cc,
                       prev_states.astype(xc.dtype), in_decay)
    y = (y_diag + y_off).reshape(bb, s, h, p)
    return y, final


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv1d. x (B, S, C), w (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(width))
    return out + bias


def mamba_apply(params: Shapes, x: jnp.ndarray, cfg: ArchConfig,
                cache: Optional[Dict[str, jnp.ndarray]] = None):
    """Full-sequence (cache=None) or single-step decode (cache given)."""
    d_inner, n_heads, n, width = _dims(cfg)
    bsz, s, _ = x.shape
    zxbcdt = x @ params["in_proj"]
    z, xin, b_mat, c_mat, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n], axis=-1)
    conv_in = jnp.concatenate([xin, b_mat, c_mat], axis=-1)    # (B,S,conv_ch)

    if cache is None:
        conv_out = jax.nn.silu(_causal_conv(conv_in, params["conv_w"],
                                            params["conv_bias"]))
        new_cache = None
    else:
        buf = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B, W, C)
        conv_out = jax.nn.silu(
            jnp.sum(buf * params["conv_w"][None], axis=1, keepdims=True)
            + params["conv_bias"])
        new_conv = buf[:, 1:, :]
        new_cache = {"conv": new_conv}

    xin, b_mat, c_mat = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    xh = xin.reshape(bsz, s, n_heads, -1)                      # (B,S,H,P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    a = -jnp.exp(params["A_log"].astype(jnp.float32))

    if getattr(cfg, "shard_ssm_heads", False) and cache is None:
        # §Perf B6: SSD heads are embarrassingly parallel — pin the head dim
        # to the 'model' mesh axis so the (b, c, h, l, l) within-chunk decay
        # tensors shard 16× with zero resharding (the baseline left XLA to
        # spatially repartition them with all-to-alls every scan step).
        from jax.sharding import PartitionSpec as P
        try:
            xh = jax.lax.with_sharding_constraint(
                xh, P("data", None, "model", None))
            dt = jax.lax.with_sharding_constraint(dt, P("data", None, "model"))
        except (ValueError, RuntimeError):
            pass   # no mesh in scope (single-device smoke tests)

    if cache is None:
        y, final = ssd_chunked(xh.astype(jnp.float32), dt, a,
                               b_mat.astype(jnp.float32), c_mat.astype(jnp.float32),
                               chunk=min(cfg.ssm.chunk, s))
    else:
        # recurrence: h ← exp(Δa)h + Δ·B·x ;  y = C·h
        hstate = cache["ssm"]                                  # (B,H,P,N) f32
        dt1 = dt[:, 0]                                         # (B,H)
        da = jnp.exp(dt1 * a[None, :])                         # (B,H)
        bx = jnp.einsum("bn,bhp,bh->bhpn", b_mat[:, 0].astype(jnp.float32),
                        xh[:, 0].astype(jnp.float32), dt1)
        hstate = hstate * da[..., None, None] + bx
        y = jnp.einsum("bn,bhpn->bhp", c_mat[:, 0].astype(jnp.float32), hstate)
        y = y[:, None]                                         # (B,1,H,P)
        new_cache["ssm"] = hstate

    y = y + xh.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner)
    y = rms_norm(y.astype(x.dtype) * jax.nn.silu(z), params["gate_norm_scale"],
                 cfg.norm_eps)
    return y @ params["out_proj"], new_cache


def mamba_cache_shapes(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> Shapes:
    d_inner, n_heads, n, width = _dims(cfg)
    conv_ch = d_inner + 2 * n
    return {
        "conv": sds(batch, width - 1, conv_ch, dtype=dtype),
        "ssm": sds(batch, n_heads, cfg.ssm.head_dim, n, dtype=jnp.float32),
    }
