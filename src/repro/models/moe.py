"""Mixture-of-Experts FFN with capacity-bounded scatter dispatch.

TPU adaptation notes (DESIGN.md §3): instead of the GShard one-hot dispatch
einsum — whose (T, E, C) tensor is astronomically large at our token counts —
tokens are routed by computing a flat destination slot ``e·C + pos_in_expert``
(cumsum over the top-k expert assignments) and scatter-added into the
(E·C, d) expert input buffer. Combine is the transposed gather weighted by
the normalized top-k gates. Both lower to efficient XLA scatter/gather and
shard cleanly with the expert-buffer (E·C) dim on the data/model axes.

Load-balance: the standard switch-style auxiliary loss (mean gate fraction ×
mean dispatch fraction per expert) is returned for the trainer to add.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models.layers import Shapes, ffn_apply, ffn_shapes, sds


def moe_shapes(cfg: ArchConfig) -> Shapes:
    m = cfg.moe
    d, f, e = cfg.d_model, m.d_ff_expert, m.num_experts
    s: Shapes = {
        "router": sds(d, e),
        "w_gate_e": sds(e, d, f),
        "w_up_e": sds(e, d, f),
        "w_down_e": sds(e, f, d),
    }
    if m.num_shared_experts:
        s["shared"] = ffn_shapes(cfg, d_ff=m.d_ff_shared)
    return s


def moe_apply(params: Shapes, x: jnp.ndarray, cfg: ArchConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) → (y, aux_loss)."""
    m = cfg.moe
    capacity_factor = m.capacity_factor
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    xf = x.reshape(t, d)

    logits = (xf.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                        # (T, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                # (T, k)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    if s == 1:
        # decode: drop-free (worst case every token routes to one expert);
        # T is just the batch here so the buffer stays small
        capacity = t
    else:
        capacity = max(int(t * k / e * capacity_factor), 1)

    # position of each (token, slot) within its expert: cumsum over the
    # token-major flattening of the one-hot assignments
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.int32)        # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                          # (T*k, E)
    pos_in_expert = jnp.sum(pos * flat, axis=-1).reshape(t, k)     # (T, k)
    keep = pos_in_expert < capacity
    dest = expert_idx * capacity + pos_in_expert                   # (T, k)
    dest = jnp.where(keep, dest, e * capacity)                     # overflow slot

    # dispatch: scatter tokens into the (E*C [+1 overflow], d) buffer
    buf = jnp.zeros((e * capacity + 1, d), xf.dtype)
    buf = buf.at[dest.reshape(-1)].add(
        jnp.repeat(xf, k, axis=0).reshape(t * k, d)
        * keep.reshape(t * k, 1).astype(xf.dtype))
    expert_in = buf[:e * capacity].reshape(e, capacity, d)

    # expert FFN (batched over E): swiglu
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", expert_in, params["w_gate_e"])) \
        * jnp.einsum("ecd,edf->ecf", expert_in, params["w_up_e"])
    expert_out = jnp.einsum("ecf,efd->ecd", h, params["w_down_e"])

    # combine: gather back and weight by gates
    flat_out = jnp.concatenate(
        [expert_out.reshape(e * capacity, d),
         jnp.zeros((1, d), expert_out.dtype)], axis=0)
    gathered = flat_out[dest.reshape(-1)].reshape(t, k, d)
    y = jnp.sum(gathered * gate_vals[..., None].astype(gathered.dtype), axis=1)

    if m.num_shared_experts:
        shared_cfg = cfg  # swiglu shared ffn
        y = y + ffn_apply(params["shared"], xf, cfg).astype(y.dtype)

    # switch aux loss
    me = jnp.mean(probs, axis=0)                                   # mean gate
    ce = jnp.mean(jnp.sum(onehot, axis=1).astype(jnp.float32), axis=0)  # dispatch frac
    aux = e * jnp.sum(me * ce) / k

    return y.reshape(b, s, d).astype(x.dtype), aux
