"""Shared transformer building blocks (pure functional, shape-declared).

Every module declares its parameters as a nested dict of
``jax.ShapeDtypeStruct`` (so the multi-pod dry-run can lower without ever
allocating weights) and applies them with a pure function. ``init_params``
materializes any shape tree for the CPU smoke tests / examples.

Attention is *blocked* (flash-style lax.scan over KV chunks with an online
softmax) so that train/prefill never materialize an (S, S) score matrix —
XLA does not perform this fusion on its own and a 32k×32k score tensor per
head would dwarf HBM. This is the pure-JAX analogue of the Pallas kernels in
repro.kernels and is what the dry-run lowers; on real TPU the Pallas path
can be swapped in per layer.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig

Shapes = Dict[str, Any]


def sds(*shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


# ------------------------------------------------------------------- init --
def init_params(key: jax.Array, shapes, base_std: float = 0.02):
    """Materialize a ShapeDtypeStruct tree: *scale→1, *bias→0, else N(0,σ)."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    keys = jax.random.split(key, max(len(leaves), 1))
    out = []
    for (path, leaf), k in zip(leaves, keys):
        name = str(path[-1])
        if "scale" in name:
            out.append(jnp.ones(leaf.shape, leaf.dtype))
        elif "bias" in name or name.endswith("_b']") or "conv_b" in name:
            out.append(jnp.zeros(leaf.shape, leaf.dtype))
        elif "A_log" in name:
            out.append(jnp.log(jnp.linspace(1.0, 16.0, leaf.shape[-1], dtype=jnp.float32))
                       .astype(leaf.dtype) if leaf.ndim == 1 else
                       jnp.zeros(leaf.shape, leaf.dtype))
        else:
            out.append((base_std * jax.random.normal(k, leaf.shape)).astype(leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)


# ------------------------------------------------------------------- norm --
def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dtype)


# ------------------------------------------------------------------- rope --
def _rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, dh); positions: broadcastable to (..., S). Split-half."""
    dh = x.shape[-1]
    freqs = _rope_freqs(dh, theta)                       # (dh/2,)
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, dh/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int] = (1, 1, 2)) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: the dh/2 frequency dims are split into
    temporal/height/width sections, each rotated by its own position stream.

    x: (..., S, H, dh); positions3: (3, ..., S)."""
    dh = x.shape[-1]
    half = dh // 2
    sec = [half * s // sum(sections) for s in sections]
    sec[-1] = half - sec[0] - sec[1]
    freqs = _rope_freqs(dh, theta)                       # (half,)
    # per-frequency position stream id: [t]*sec0 + [h]*sec1 + [w]*sec2
    stream = jnp.concatenate([
        jnp.zeros((sec[0],), jnp.int32),
        jnp.ones((sec[1],), jnp.int32),
        jnp.full((sec[2],), 2, jnp.int32)])
    pos = jnp.take(positions3, stream, axis=0)           # (half, ..., S) via axis-0 gather
    pos = jnp.moveaxis(pos, 0, -1)                       # (..., S, half)
    angles = pos[..., :, None, :].astype(jnp.float32) * freqs   # (..., S, 1, half)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -------------------------------------------------------------------- ffn --
def ffn_shapes(cfg: ArchConfig, d_ff: Optional[int] = None) -> Shapes:
    d, f = cfg.d_model, d_ff if d_ff is not None else cfg.d_ff
    if cfg.activation in ("swiglu", "geglu"):
        return {"w_gate": sds(d, f), "w_up": sds(d, f), "w_down": sds(f, d)}
    return {"w_up": sds(d, f), "w_down": sds(f, d)}


def ffn_apply(params: Shapes, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.activation == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif cfg.activation == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    else:  # gelu
        h = jax.nn.gelu(x @ params["w_up"])
    return h @ params["w_down"]


# -------------------------------------------------------- blocked attention --
def _attend_block_scan(q, k, v, q_pos, k_pos, window: Optional[int],
                       causal: bool, kv_chunk: int,
                       shard_heads: bool = False):
    """Online-softmax attention, scanning KV chunks.

    q: (B, Sq, H, dh); k/v: (B, Sk, Hkv, dh); *_pos: (B, S*) int32.
    Returns (B, Sq, H, dh) in q.dtype. Grouped heads handled by reshape.

    shard_heads (§Perf A3): pin the grouped-query-head dim to the 'model'
    mesh axis so the (b, sq, hkv, g, L) score/softmax tensors shard without
    resharding; K/V stay replicated across model (the GQA standard — kv
    heads are usually fewer than the model-axis size).
    """
    b, sq, h, dh = q.shape
    _, sk, hkv, _ = k.shape
    dv = v.shape[-1]              # may differ from dh (MLA)
    g = h // hkv
    scale = 1.0 / math.sqrt(dh)
    qf = (q.astype(jnp.float32) * scale).reshape(b, sq, hkv, g, dh)
    if shard_heads:
        from jax.sharding import PartitionSpec as P
        # pin whichever head axis is larger: GQA has few kv heads and many
        # groups (shard g); MLA/MHA has g == 1 (shard hkv) — pinning a size-1
        # dim would force full resharding instead (§Perf deepseek post-mortem)
        spec = (P("data", None, None, "model", None) if g >= hkv
                else P("data", None, "model", None, None))
        try:
            qf = jax.lax.with_sharding_constraint(qf, spec)
        except (ValueError, RuntimeError):
            pass

    n_chunks = sk // kv_chunk
    assert n_chunks * kv_chunk == sk, (sk, kv_chunk)
    kc = k.astype(jnp.float32).reshape(b, n_chunks, kv_chunk, hkv, dh)
    vc = v.astype(jnp.float32).reshape(b, n_chunks, kv_chunk, hkv, dv)
    kpos = k_pos.reshape(b, n_chunks, kv_chunk)

    def body(carry, inputs):
        m, l, acc = carry
        k_blk, v_blk, kp = inputs                        # (b, L, hkv, dh), (b, L)
        s = jnp.einsum("bqkgd,blkd->bqkgl", qf, k_blk)   # (b, sq, hkv, g, L)
        dpos = q_pos[:, :, None, None, None] - kp[:, None, None, None, :]
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= dpos >= 0
        if window is not None:
            mask &= dpos < window
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum("bqkgl,blkd->bqkgd", p, v_blk)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, hkv, g), -1e30, jnp.float32)
    l0 = jnp.zeros((b, sq, hkv, g), jnp.float32)
    acc0 = jnp.zeros((b, sq, hkv, g, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(kpos, 1, 0)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def attention_shapes(cfg: ArchConfig) -> Shapes:
    d, h, hkv, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    s: Shapes = {
        "w_q": sds(d, h * dh),
        "w_k": sds(d, hkv * dh),
        "w_v": sds(d, hkv * dh),
        "w_o": sds(h * dh, d),
    }
    if cfg.qkv_bias:
        s["b_q"] = sds(h * dh)
        s["b_k"] = sds(hkv * dh)
        s["b_v"] = sds(hkv * dh)
    return s


def attention_apply(params: Shapes, x: jnp.ndarray, cfg: ArchConfig,
                    positions: jnp.ndarray,
                    positions3: Optional[jnp.ndarray] = None,
                    kv_chunk: int = 1024,
                    window: Optional[int] = None,
                    cross_kv: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                    cache: Optional[Dict[str, jnp.ndarray]] = None):
    """Self- or cross-attention. With ``cache`` (decode): x is (B, 1, d) and
    the cache dict {k, v, index} is functionally updated and returned."""
    b, s, d = x.shape
    h, hkv, dh = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    q = (x @ params["w_q"])
    if cfg.qkv_bias:
        q = q + params["b_q"]
    q = q.reshape(b, s, h, dh)

    if cross_kv is not None:
        k, v = cross_kv
        new_cache = None
        out = _attend_block_scan(q, k, v, positions,
                                 jnp.broadcast_to(jnp.arange(k.shape[1])[None], k.shape[:2]),
                                 window=None, causal=False, kv_chunk=min(1024, k.shape[1]))
    else:
        k = x @ params["w_k"]
        v = x @ params["w_v"]
        if cfg.qkv_bias:
            k = k + params["b_k"]
            v = v + params["b_v"]
        k = k.reshape(b, s, hkv, dh)
        v = v.reshape(b, s, hkv, dh)
        if cfg.rope_style == "mrope":
            assert positions3 is not None
            q = apply_mrope(q, positions3, cfg.rope_theta)
            k = apply_mrope(k, positions3, cfg.rope_theta)
        elif cfg.rope_style == "rope":
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)

        if cache is None:
            out = _attend_block_scan(q, k, v, positions, positions,
                                     window=window, causal=True,
                                     kv_chunk=min(kv_chunk, s),
                                     shard_heads=getattr(cfg, "shard_attn_heads", False))
            new_cache = None
        else:
            # decode: append this token's k/v at cache[index] (ring buffer for
            # sliding window), attend over the whole cache
            idx = cache["index"]                         # scalar int32
            cache_len = cache["k"].shape[1]
            slot = idx % cache_len if window is not None else idx
            ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                              (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                              (0, slot, 0, 0))
            # positions of cache slots for masking, stored +1 (0 = empty slot)
            kpos = cache["pos"]
            kpos = jax.lax.dynamic_update_slice(
                kpos, positions.astype(kpos.dtype) + 1, (0, slot))
            qf = (q.astype(jnp.float32) / math.sqrt(dh)).reshape(b, 1, hkv, h // hkv, dh)
            scores = jnp.einsum("bqkgd,blkd->bqkgl", qf, ck.astype(jnp.float32))
            dpos = positions[:, :, None, None, None] - (kpos[:, None, None, None, :] - 1)
            mask = (dpos >= 0) & (kpos[:, None, None, None, :] > 0)
            if window is not None:
                mask &= dpos < window
            scores = jnp.where(mask, scores, -1e30)
            p = jax.nn.softmax(scores, axis=-1)
            out = jnp.einsum("bqkgl,blkd->bqkgd", p, cv.astype(jnp.float32))
            out = out.reshape(b, 1, h, dh).astype(x.dtype)
            new_cache = {"k": ck, "v": cv, "pos": kpos, "index": idx + 1}

    y = out.reshape(b, s, h * dh) @ params["w_o"]
    return y, new_cache


def attention_cache_shapes(cfg: ArchConfig, batch: int, cache_len: int,
                           dtype=jnp.bfloat16) -> Shapes:
    hkv, dh = cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "k": sds(batch, cache_len, hkv, dh, dtype=dtype),
        "v": sds(batch, cache_len, hkv, dh, dtype=dtype),
        "pos": sds(batch, cache_len, dtype=jnp.int32),
        "index": sds(dtype=jnp.int32),
    }


# ---------------------------------------------------------------- MLA ------
def mla_shapes(cfg: ArchConfig) -> Shapes:
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    s: Shapes = {
        "w_dkv": sds(d, m.kv_lora_rank),
        "w_kr": sds(d, m.rope_head_dim),
        "w_uk": sds(m.kv_lora_rank, h * m.nope_head_dim),
        "w_uv": sds(m.kv_lora_rank, h * m.v_head_dim),
        "w_o": sds(h * m.v_head_dim, d),
        "kv_norm_scale": sds(m.kv_lora_rank),
    }
    if m.q_lora_rank:
        s["w_dq"] = sds(d, m.q_lora_rank)
        s["q_norm_scale"] = sds(m.q_lora_rank)
        s["w_uq"] = sds(m.q_lora_rank, h * (m.nope_head_dim + m.rope_head_dim))
    else:
        s["w_q"] = sds(d, h * (m.nope_head_dim + m.rope_head_dim))
    return s


def mla_apply(params: Shapes, x: jnp.ndarray, cfg: ArchConfig,
              positions: jnp.ndarray, kv_chunk: int = 1024,
              window: Optional[int] = None,
              cache: Optional[Dict[str, jnp.ndarray]] = None):
    """DeepSeek-V2 Multi-head Latent Attention.

    Train/prefill: expand the latent to per-head K/V (checkpoint-friendly).
    Decode: ABSORBED form — queries are mapped into the latent space so the
    cache stays (B, S, kv_lora + rope_dim) and attention is two thin matmuls
    per token (the published serving optimization)."""
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.num_heads
    dn, dr, dv = m.nope_head_dim, m.rope_head_dim, m.v_head_dim

    if m.q_lora_rank:
        q_lat = rms_norm(x @ params["w_dq"], params["q_norm_scale"], cfg.norm_eps)
        q = (q_lat @ params["w_uq"]).reshape(b, s, h, dn + dr)
    else:
        q = (x @ params["w_q"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    c_kv = rms_norm(x @ params["w_dkv"], params["kv_norm_scale"], cfg.norm_eps)  # (b,s,r)
    k_rope = apply_rope((x @ params["w_kr"]).reshape(b, s, 1, dr), positions,
                        cfg.rope_theta)                                           # shared

    scale = 1.0 / math.sqrt(dn + dr)

    if cache is None:
        k_nope = (c_kv @ params["w_uk"]).reshape(b, s, h, dn)
        v = (c_kv @ params["w_uv"]).reshape(b, s, h, dv)
        k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], axis=-1)
        qq = jnp.concatenate([q_nope, q_rope], axis=-1)
        out = _attend_block_scan(qq, k, v, positions, positions,
                                 window=window, causal=True,
                                 kv_chunk=min(kv_chunk, s),
                                 shard_heads=getattr(cfg, "shard_attn_heads", False))
        y = out.reshape(b, s, h * dv) @ params["w_o"]
        return y, None

    # ---------------- absorbed decode ----------------
    idx = cache["index"]
    cc = jax.lax.dynamic_update_slice(cache["c_kv"], c_kv.astype(cache["c_kv"].dtype),
                                      (0, idx, 0))
    ckr = jax.lax.dynamic_update_slice(cache["k_rope"],
                                       k_rope[:, :, 0, :].astype(cache["k_rope"].dtype),
                                       (0, idx, 0))
    # stored +1 (0 = empty slot)
    kpos = jax.lax.dynamic_update_slice(cache["pos"],
                                        positions.astype(jnp.int32) + 1, (0, idx))
    # absorb: q_lat[h] = q_nope[h] @ W_uk[h]^T  → latent-space queries
    w_uk = params["w_uk"].reshape(m.kv_lora_rank, h, dn)
    q_lat = jnp.einsum("bshd,rhd->bshr", q_nope, w_uk)          # (b, 1, h, r)
    s_lat = jnp.einsum("bshr,blr->bshl", q_lat, cc.astype(jnp.float32))
    s_rope = jnp.einsum("bshd,bld->bshl", q_rope, ckr.astype(jnp.float32))
    scores = (s_lat + s_rope) * scale
    dpos = positions[:, :, None, None] - (kpos[:, None, None, :] - 1)
    mask = (dpos >= 0) & (kpos[:, None, None, :] > 0)
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)                          # (b, 1, h, L)
    o_lat = jnp.einsum("bshl,blr->bshr", p, cc.astype(jnp.float32))  # (b,1,h,r)
    w_uv = params["w_uv"].reshape(m.kv_lora_rank, h, dv)
    out = jnp.einsum("bshr,rhd->bshd", o_lat, w_uv)              # absorbed W_uv
    y = out.reshape(b, s, h * dv).astype(x.dtype) @ params["w_o"]
    return y, {"c_kv": cc, "k_rope": ckr, "pos": kpos, "index": idx + 1}


def mla_cache_shapes(cfg: ArchConfig, batch: int, cache_len: int,
                     dtype=jnp.bfloat16) -> Shapes:
    m = cfg.mla
    return {
        "c_kv": sds(batch, cache_len, m.kv_lora_rank, dtype=dtype),
        "k_rope": sds(batch, cache_len, m.rope_head_dim, dtype=dtype),
        "pos": sds(batch, cache_len, dtype=jnp.int32),
        "index": sds(dtype=jnp.int32),
    }


# -------------------------------------------------------------- embedding --
def embedding_shapes(cfg: ArchConfig) -> Shapes:
    s: Shapes = {"tok": sds(cfg.vocab_size, cfg.d_model)}
    if not cfg.tie_embeddings:
        s["unembed"] = sds(cfg.d_model, cfg.vocab_size)
    return s


def embed(params: Shapes, tokens: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    e = jnp.take(params["tok"], tokens, axis=0)
    if cfg.name.startswith("gemma"):
        e = e * math.sqrt(cfg.d_model)
    return e.astype(jnp.bfloat16 if cfg.activation_dtype == "bfloat16" else jnp.float32)


def unembed(params: Shapes, x: jnp.ndarray, cfg: ArchConfig) -> jnp.ndarray:
    if cfg.tie_embeddings:
        return x @ params["tok"].T.astype(x.dtype)
    return x @ params["unembed"].astype(x.dtype)
