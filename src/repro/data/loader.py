"""Host-side batching with deterministic shuffling.

Also exposes ``load_real_or_synthetic`` so that on a machine with the actual
CIFAR-10 / UCI files the paper's exact experiments run unchanged.
"""
from __future__ import annotations

import os
from typing import Iterator, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def epoch_batches(n: int, batch_size: int, seed: int, drop_remainder: bool = True):
    """Yield index arrays for one shuffled epoch."""
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    end = (n // batch_size) * batch_size if drop_remainder else n
    for s in range(0, end, batch_size):
        yield perm[s:s + batch_size]


def batch_iterator(arrays: Sequence[jnp.ndarray], batch_size: int, epochs: int,
                   seed: int = 0, drop_remainder: bool = True) -> Iterator[Tuple[jnp.ndarray, ...]]:
    """Iterate shuffled minibatches over aligned arrays for ``epochs`` epochs."""
    n = arrays[0].shape[0]
    for e in range(epochs):
        for idx in epoch_batches(n, batch_size, seed + e, drop_remainder):
            yield tuple(a[idx] for a in arrays)


def load_real_or_synthetic(kind: str, key: jax.Array, num_samples: int, data_dir: Optional[str] = None):
    """Return (x, y). Uses real CIFAR-10 / UCI csv when present under data_dir."""
    from repro.data import synthetic

    data_dir = data_dir or os.environ.get("REPRO_DATA_DIR", "")
    if kind == "image":
        path = os.path.join(data_dir, "cifar10.npz") if data_dir else ""
        if path and os.path.exists(path):
            blob = np.load(path)
            x = jnp.asarray(blob["x"], jnp.float32)
            x = (x - x.mean()) / (x.std() + 1e-6)
            return x[:num_samples], jnp.asarray(blob["y"], jnp.int32)[:num_samples]
        return synthetic.make_image_classification(key, num_samples)
    if kind == "tabular":
        path = os.path.join(data_dir, "uci_credit.npz") if data_dir else ""
        if path and os.path.exists(path):
            blob = np.load(path)
            x = jnp.asarray(blob["x"], jnp.float32)
            x = (x - x.mean(0)) / (x.std(0) + 1e-6)
            return x[:num_samples], jnp.asarray(blob["y"], jnp.int32)[:num_samples]
        return synthetic.make_tabular_credit(key, num_samples)
    raise ValueError(f"unknown kind {kind!r}")
