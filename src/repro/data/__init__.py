from repro.data.synthetic import (
    make_cluster_tabular,
    make_image_classification,
    make_tabular_credit,
    make_token_stream,
)
from repro.data.vertical import (VerticalSplit, make_vfl_partition,
                                 split_features, split_image_halves,
                                 split_image_patches)
from repro.data.loader import batch_iterator, epoch_batches

__all__ = [
    "make_cluster_tabular",
    "make_image_classification",
    "make_tabular_credit",
    "make_token_stream",
    "VerticalSplit",
    "split_features",
    "split_image_halves",
    "split_image_patches",
    "make_vfl_partition",
    "batch_iterator",
    "epoch_batches",
]
