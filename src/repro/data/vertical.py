"""Vertical (feature-space) dataset partitioning for VFL.

Implements the paper's data protocol (§5.1):
* images are split into left/right halves (K=2) or K vertical strips;
* tabular features are split into contiguous blocks (10 / rest for credit);
* ``make_vfl_partition`` samples ``N_o`` overlapping (entity-aligned) rows and
  distributes the remainder evenly as party-private *unaligned* pools.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence

import jax.numpy as jnp
import numpy as np


@dataclass
class VerticalSplit:
    """The VFL view of one dataset.

    Attributes:
      aligned:   list (len K) of per-party feature slices of the N_o
                 overlapping samples, row-aligned across parties.
      labels:    (N_o,) labels held by the server only.
      unaligned: list (len K) of per-party private pools (different rows per
                 party — *not* aligned with each other).
      test_aligned / test_labels: held-out aligned evaluation split.
    """

    aligned: List[jnp.ndarray]
    labels: jnp.ndarray
    unaligned: List[jnp.ndarray]
    test_aligned: List[jnp.ndarray]
    test_labels: jnp.ndarray
    num_classes: int
    unaligned_labels: Optional[List[jnp.ndarray]] = None  # for oracle diagnostics only
    #: validity mask over the aligned rows when the partition was built with a
    #: fixed ``overlap_capacity`` (equal-shape overlap family): 1.0 for real
    #: overlap rows, 0.0 for the cyclic-duplicate padding rows. ``None`` means
    #: every aligned row is real (the historical exact-N_o layout).
    aligned_mask: Optional[jnp.ndarray] = None


def split_image_halves(x: jnp.ndarray, num_parties: int = 2) -> List[jnp.ndarray]:
    """Split (N, H, W, C) images into vertical strips along W (paper: halves)."""
    W = x.shape[2]
    widths = [W // num_parties] * num_parties
    widths[-1] += W - sum(widths)
    out, start = [], 0
    for w in widths:
        out.append(x[:, :, start:start + w, :])
        start += w
    return out


def split_image_patches(x: jnp.ndarray, grid: Sequence[int] = (2, 2)
                        ) -> List[jnp.ndarray]:
    """Split (N, H, W, C) images into a ``grid = (rows, cols)`` of patches —
    the K = rows×cols image-*patch* party layout (e.g. 4 parties each hold
    one quadrant), generalizing the paper's vertical-strip split."""
    rows, cols = grid
    H, W = x.shape[1], x.shape[2]
    hs = [H // rows] * rows
    hs[-1] += H - sum(hs)
    ws = [W // cols] * cols
    ws[-1] += W - sum(ws)
    out = []
    r0 = 0
    for h in hs:
        c0 = 0
        for w in ws:
            out.append(x[:, r0:r0 + h, c0:c0 + w, :])
            c0 += w
        r0 += h
    return out


def split_features(x: jnp.ndarray, sizes: Sequence[int]) -> List[jnp.ndarray]:
    """Split (N, D) feature matrix into contiguous blocks of given sizes."""
    assert sum(sizes) == x.shape[1], (sizes, x.shape)
    out, start = [], 0
    for s in sizes:
        out.append(x[:, start:start + s])
        start += s
    return out


def _split_fn_for(x: jnp.ndarray, num_parties: int,
                  feature_sizes: Optional[Sequence[int]],
                  image_grid: Optional[Sequence[int]] = None):
    if x.ndim == 4:
        if image_grid is not None:
            assert image_grid[0] * image_grid[1] == num_parties, (
                image_grid, num_parties)
            return lambda arr: split_image_patches(arr, image_grid)
        return lambda arr: split_image_halves(arr, num_parties)
    if feature_sizes is None:
        d = x.shape[1]
        base = d // num_parties
        feature_sizes = [base] * num_parties
        feature_sizes[-1] += d - base * num_parties
    return lambda arr: split_features(arr, feature_sizes)


def make_vfl_partition(
    x: jnp.ndarray,
    y: jnp.ndarray,
    overlap_size: int,
    num_parties: int = 2,
    test_fraction: float = 0.2,
    feature_sizes: Optional[Sequence[int]] = None,
    seed: int = 0,
    num_classes: Optional[int] = None,
    image_grid: Optional[Sequence[int]] = None,
    overlap_capacity: Optional[int] = None,
) -> VerticalSplit:
    """Sample N_o aligned rows; split the rest evenly into private pools.

    ``overlap_capacity`` builds the equal-shape variant (DESIGN.md §14): the
    aligned block always holds ``capacity`` rows — the first ``overlap_size``
    are the real overlap, the remainder are cyclic duplicates of them — and
    ``aligned_mask`` marks which rows are real. The first ``capacity`` rows
    of the shuffled training pool are *reserved* for the aligned block
    regardless of ``overlap_size``, so every member of one equal-shape family
    (same capacity, different N_o) sees identical private pools and identical
    array shapes, letting the engine stack them into one program.
    """
    n = x.shape[0]
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    n_test = int(n * test_fraction)
    test_idx = perm[:n_test]
    rest = perm[n_test:]
    # overlap_size == len(rest) is the full-overlap edge: every training row
    # is aligned and the per-party private pools are empty (0, d_k) arrays —
    # the engine schedules zero-width unlabeled batches for them
    assert overlap_size <= len(rest), "not enough rows for this overlap"
    aligned_mask = None
    if overlap_capacity is not None:
        capacity = int(overlap_capacity)
        assert overlap_size <= capacity, (overlap_size, capacity)
        assert capacity <= len(rest), "not enough rows for this capacity"
        real = rest[:overlap_size]
        pad = capacity - overlap_size
        aligned_idx = np.concatenate(
            [real, real[np.arange(pad) % overlap_size]]) if pad else real
        aligned_mask = jnp.concatenate(
            [jnp.ones(overlap_size, jnp.float32),
             jnp.zeros(pad, jnp.float32)])
        pool = rest[capacity:]   # reserve the full capacity: equal pools
    else:
        aligned_idx = rest[:overlap_size]
        pool = rest[overlap_size:]
    per = len(pool) // num_parties
    party_idx = [pool[k * per:(k + 1) * per] for k in range(num_parties)]

    split = _split_fn_for(x, num_parties, feature_sizes, image_grid)
    aligned_parts = split(jnp.asarray(x)[aligned_idx])
    test_parts = split(jnp.asarray(x)[test_idx])
    unaligned_parts, unaligned_labels = [], []
    for k in range(num_parties):
        unaligned_parts.append(split(jnp.asarray(x)[party_idx[k]])[k])
        unaligned_labels.append(jnp.asarray(y)[party_idx[k]])

    if num_classes is None:
        num_classes = int(jnp.max(y)) + 1
    return VerticalSplit(
        aligned=aligned_parts,
        labels=jnp.asarray(y)[aligned_idx],
        unaligned=unaligned_parts,
        test_aligned=test_parts,
        test_labels=jnp.asarray(y)[test_idx],
        num_classes=num_classes,
        unaligned_labels=unaligned_labels,
        aligned_mask=aligned_mask,
    )
