"""Structured synthetic datasets.

The container ships no datasets, so the experiment drivers default to
class-structured synthetics that preserve the *shape* of the paper's tasks:

* ``make_image_classification`` — CIFAR-like (N, 32, 32, 3) Gaussian-mixture
  textures. Each class has a low-frequency spatial template plus per-sample
  texture noise, so that (a) halves of the image are individually informative
  but (b) the joint image is more informative than either half — the property
  the paper's toy example (Fig. 4) relies on.
* ``make_tabular_credit`` — UCI-credit-like (N, 23) correlated features with a
  logistic label model spanning both parties' feature blocks.
* ``make_token_stream`` — synthetic token ids for LM smoke tests.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def make_image_classification(
    key: jax.Array,
    num_samples: int,
    num_classes: int = 10,
    image_size: int = 32,
    channels: int = 3,
    template_strength: float = 1.0,
    cross_half_fraction: float = 0.35,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Class-conditional low-frequency templates + noise.

    ``cross_half_fraction`` of each class template's energy lives in a
    component that is only label-informative when both halves are observed
    (an odd/even parity pattern across the vertical midline), mimicking the
    paper's Fig.-4 phenomenon where one half alone is ambiguous.
    """
    k_tmpl, k_cross, k_lbl, k_noise, k_phase = jax.random.split(key, 5)
    H = W = image_size
    # Low-frequency per-class template: random coefficients on a 4x4 Fourier-ish
    # basis, upsampled.
    coarse = jax.random.normal(k_tmpl, (num_classes, 4, 4, channels))
    templates = jax.image.resize(coarse, (num_classes, H, W, channels), "bilinear")
    # Cross-half component: sign-coupled pattern between left and right halves.
    cross = jax.random.normal(k_cross, (num_classes, H, W // 2, channels))
    cross_full = jnp.concatenate([cross, cross * ((-1.0) ** jnp.arange(num_classes))[:, None, None, None]], axis=2)
    templates = (1 - cross_half_fraction) * templates + cross_half_fraction * cross_full

    labels = jax.random.randint(k_lbl, (num_samples,), 0, num_classes)
    noise = jax.random.normal(k_noise, (num_samples, H, W, channels))
    x = template_strength * templates[labels] + noise
    # Normalize to roughly unit scale like standardized CIFAR.
    x = x / (1.0 + template_strength)
    return x.astype(jnp.float32), labels.astype(jnp.int32)


def make_tabular_credit(
    key: jax.Array,
    num_samples: int,
    num_features: int = 23,
    num_classes: int = 2,
    label_noise: float = 0.05,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Correlated features; the label depends on features from BOTH parties'
    blocks (first 10 / rest), matching the FATE split used by the paper."""
    k_mix, k_x, k_w, k_flip = jax.random.split(key, 4)
    # Correlated features: x = z @ M with a random mixing matrix.
    latent = jax.random.normal(k_x, (num_samples, num_features))
    mix = jax.random.normal(k_mix, (num_features, num_features)) / jnp.sqrt(num_features)
    mix = mix + 0.5 * jnp.eye(num_features)
    x = latent @ mix
    w = jax.random.normal(k_w, (num_features,))
    logits = x @ w + 0.25 * (x[:, 2] * x[:, 12])  # cross-party interaction
    if num_classes == 2:
        y = (logits > jnp.median(logits)).astype(jnp.int32)
    else:
        qs = jnp.quantile(logits, jnp.linspace(0, 1, num_classes + 1)[1:-1])
        y = jnp.sum(logits[:, None] > qs[None, :], axis=1).astype(jnp.int32)
    flip = jax.random.bernoulli(k_flip, label_noise, (num_samples,))
    y = jnp.where(flip, (y + 1) % num_classes, y)
    return x.astype(jnp.float32), y


def make_cluster_tabular(
    key: jax.Array,
    num_samples: int,
    num_informative: int = 24,
    num_nuisance: int = 16,
    num_clusters: int = 12,
    num_classes: int = 2,
    cluster_std: float = 0.3,
    nuisance_std: float = 2.0,
    label_noise: float = 0.15,
    separation: float = 3.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The *hardened* tabular task (scenario family ``hard/*``).

    A Gaussian mixture of compact, well-separated clusters, with 16 of 40
    feature dimensions pure high-variance nuisance noise and 15% label
    flips on top. A supervised fit of a tiny overlap places its decision
    boundary from 1–3 *noisy* points per cluster and latches onto nuisance
    dimensions; semi-supervised local training on the party-private pools
    (thousands of unlabeled rows) recovers the cluster structure via the
    consistency term — the regime where the paper's one-shot VFL beats
    iterative VFL outright (validated over seeds in tests/test_scenarios
    and gated in benchmarks/frontier.py).

    Informative and nuisance columns are interleaved so that every party's
    feature block contains both kinds.
    """
    ks = jax.random.split(key, 6)
    centers = jax.random.normal(ks[0], (num_clusters, num_informative))
    centers = (separation * centers
               / jnp.linalg.norm(centers, axis=1, keepdims=True)
               * jnp.sqrt(num_informative / 8))
    z = jax.random.randint(ks[1], (num_samples,), 0, num_clusters)
    x_inf = centers[z] + cluster_std * jax.random.normal(
        ks[2], (num_samples, num_informative))
    x_nui = nuisance_std * jax.random.normal(ks[3],
                                             (num_samples, num_nuisance))
    cls = jnp.arange(num_clusters) % num_classes
    y = cls[z]
    # ks[4] is reserved (a dropped label-model draw); renumbering the key
    # split would shift every downstream draw and invalidate the margins
    # validated over seeds 0-3 — keep the split width stable
    flip = jax.random.bernoulli(ks[5], label_noise, (num_samples,))
    y = jnp.where(flip, (y + 1) % num_classes, y).astype(jnp.int32)
    half_i, half_n = num_informative // 2, num_nuisance // 2
    x = jnp.concatenate([x_inf[:, :half_i], x_nui[:, :half_n],
                         x_inf[:, half_i:], x_nui[:, half_n:]], axis=1)
    return x.astype(jnp.float32), y


def make_token_stream(
    key: jax.Array, batch: int, seq_len: int, vocab_size: int
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Synthetic LM batch: Zipf-ish token ids; labels = next token."""
    k1, = jax.random.split(key, 1)
    # Zipf via exponentiated uniform — cheap and deterministic.
    u = jax.random.uniform(k1, (batch, seq_len + 1), minval=1e-6, maxval=1.0)
    ids = jnp.clip((u ** (-0.7) - 1.0).astype(jnp.int32), 0, vocab_size - 1)
    return ids[:, :-1], ids[:, 1:]


def make_sequence_classification(
    key: jax.Array, num_samples: int, seq_len: int = 32, vocab_size: int = 64,
    num_classes: int = 4, topic_strength: float = 0.5
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Token sequences whose class is a 'topic': each class over-samples a
    class-specific token subset, spread across the WHOLE sequence so both
    sequence-halves are informative (the VFL-on-LM scenario)."""
    k_topic, k_lbl, k_tok, k_mix = jax.random.split(key, 4)
    topics = jax.random.randint(k_topic, (num_classes, vocab_size // 4), 1,
                                vocab_size)
    labels = jax.random.randint(k_lbl, (num_samples,), 0, num_classes)
    base = jax.random.randint(k_tok, (num_samples, seq_len), 1, vocab_size)
    pick = jax.random.randint(k_mix, (num_samples, seq_len), 0,
                              vocab_size // 4)
    topic_tok = topics[labels][jnp.arange(num_samples)[:, None], pick]
    use_topic = jax.random.bernoulli(k_mix, topic_strength,
                                     (num_samples, seq_len))
    return jnp.where(use_topic, topic_tok, base).astype(jnp.int32), labels


def numpy_train_test_split(x, y, test_fraction: float = 0.2, seed: int = 0):
    n = x.shape[0]
    rng = np.random.RandomState(seed)
    perm = rng.permutation(n)
    n_test = int(n * test_fraction)
    te, tr = perm[:n_test], perm[n_test:]
    return (jnp.asarray(x)[tr], jnp.asarray(y)[tr]), (jnp.asarray(x)[te], jnp.asarray(y)[te])
