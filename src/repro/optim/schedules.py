"""Learning-rate schedules as step -> lr callables (jit-safe)."""
from __future__ import annotations

import jax.numpy as jnp


def constant(value: float):
    def schedule(step):
        return jnp.asarray(value, jnp.float32)

    return schedule


def cosine_decay(init_value: float, decay_steps: int, alpha: float = 0.0):
    def schedule(step):
        t = jnp.minimum(step.astype(jnp.float32), decay_steps) / decay_steps
        cosine = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return init_value * ((1 - alpha) * cosine + alpha)

    return schedule


def linear_warmup_cosine(peak_value: float, warmup_steps: int, total_steps: int,
                         end_value: float = 0.0):
    def schedule(step):
        step = step.astype(jnp.float32)
        warm = peak_value * step / jnp.maximum(1.0, warmup_steps)
        t = jnp.clip((step - warmup_steps) / jnp.maximum(1.0, total_steps - warmup_steps),
                     0.0, 1.0)
        cos = end_value + (peak_value - end_value) * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return jnp.where(step < warmup_steps, warm, cos)

    return schedule
