"""Minimal optax-like gradient-transformation combinators.

A ``GradientTransformation`` is a pair of pure functions:

  init(params)                 -> state
  update(grads, state, params) -> (updates, state)

``apply_updates(params, updates)`` adds the (already-negated) updates.
Everything is a pytree; everything jits.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class GradientTransformation(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Optional[Any]], tuple]


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)) if u is not None else p, params, updates
    )


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s = t.update(grads, s, params)
            new_state.append(s)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in leaves))


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        norm = global_norm(grads)
        factor = jnp.minimum(1.0, max_norm / (norm + 1e-12))
        return jax.tree_util.tree_map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree_util.tree_map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


class ScaleByScheduleState(NamedTuple):
    step: jnp.ndarray


def scale_by_schedule(schedule: Callable[[jnp.ndarray], jnp.ndarray]) -> GradientTransformation:
    def init(params):
        del params
        return ScaleByScheduleState(step=jnp.zeros((), jnp.int32))

    def update(grads, state, params=None):
        del params
        s = schedule(state.step)
        grads = jax.tree_util.tree_map(lambda g: g * s, grads)
        return grads, ScaleByScheduleState(step=state.step + 1)

    return GradientTransformation(init, update)
