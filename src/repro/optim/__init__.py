"""Pure-JAX optimizers (optax-like GradientTransformation pytree API)."""
from repro.optim.transform import (
    GradientTransformation,
    apply_updates,
    chain,
    clip_by_global_norm,
    scale,
    scale_by_schedule,
)
from repro.optim.optimizers import adam, adamw, sgd
from repro.optim.schedules import constant, cosine_decay, linear_warmup_cosine

__all__ = [
    "GradientTransformation",
    "apply_updates",
    "chain",
    "clip_by_global_norm",
    "scale",
    "scale_by_schedule",
    "adam",
    "adamw",
    "sgd",
    "constant",
    "cosine_decay",
    "linear_warmup_cosine",
]
