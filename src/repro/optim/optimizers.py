"""SGD / Adam / AdamW built on the transform combinators."""
from __future__ import annotations

from typing import Callable, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

from repro.optim.transform import GradientTransformation

ScalarOrSchedule = Union[float, Callable]


def _lr(lr: ScalarOrSchedule, step):
    return lr(step) if callable(lr) else lr


class SGDState(NamedTuple):
    step: jnp.ndarray
    momentum: Optional[object]


def sgd(learning_rate: ScalarOrSchedule, momentum: float = 0.0,
        nesterov: bool = False, weight_decay: float = 0.0) -> GradientTransformation:
    def init(params):
        mom = None
        if momentum:
            mom = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return SGDState(step=jnp.zeros((), jnp.int32), momentum=mom)

    def update(grads, state, params=None):
        if weight_decay and params is not None:
            grads = jax.tree_util.tree_map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params)
        if momentum:
            mom = jax.tree_util.tree_map(
                lambda m, g: momentum * m + g.astype(jnp.float32), state.momentum, grads)
            if nesterov:
                grads = jax.tree_util.tree_map(
                    lambda m, g: momentum * m + g.astype(jnp.float32), mom, grads)
            else:
                grads = mom
        else:
            mom = state.momentum
        lr = _lr(learning_rate, state.step)
        updates = jax.tree_util.tree_map(lambda g: -lr * g, grads)
        return updates, SGDState(step=state.step + 1, momentum=mom)

    return GradientTransformation(init, update)


class AdamState(NamedTuple):
    step: jnp.ndarray
    mu: object
    nu: object


def adam(learning_rate: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.999,
         eps: float = 1e-8, weight_decay: float = 0.0) -> GradientTransformation:
    """Adam; with weight_decay>0 it is decoupled AdamW."""

    def init(params):
        mu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        nu = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return AdamState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)

    def update(grads, state, params=None):
        step = state.step + 1
        mu = jax.tree_util.tree_map(
            lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32), state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: b2 * v + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state.nu, grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)
        lr = _lr(learning_rate, state.step)

        def upd(m, v, p):
            u = -lr * (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if weight_decay and p is not None:
                u = u - lr * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree_util.tree_map(lambda m, v: upd(m, v, None), mu, nu)
        else:
            updates = jax.tree_util.tree_map(upd, mu, nu, params)
        return updates, AdamState(step=step, mu=mu, nu=nu)

    return GradientTransformation(init, update)


def adamw(learning_rate: ScalarOrSchedule, b1: float = 0.9, b2: float = 0.999,
          eps: float = 1e-8, weight_decay: float = 0.01) -> GradientTransformation:
    return adam(learning_rate, b1=b1, b2=b2, eps=eps, weight_decay=weight_decay)
